"""Table 1 reproduction: VNI multi-tenancy reachability matrix, plus the
multi-tenant churn study (ROADMAP item, ISSUE 5 satellite).

Thin wrapper over ``repro.scenario`` (ISSUE 5): the Table-1 tenant layout
is a declarative event script (``tenant_attach`` events at step 0 on the
paper's Fig. 1 fabric with no default tenant) executed by
``run_scenario``; the churn study is the library's ``multi_tenant_churn``
scenario — per-step tenant detach/attach plus a leaf-isolation flap
episode — whose :class:`repro.core.evpn.EvpnResyncStats` rollups are
surfaced here as deterministic gated metrics.

Paper host/VNI assignment: d1h1, d1h2, d2h1 on VNI 100; d1h3, d1h5 on
VNI 200 (plus d2h4 in our richer check); d1h4 on VNI 300.  Intra-VNI
pairs ping (with RTT reflecting the WAN when cross-DC); inter-VNI pairs
get "destination host unreachable".
"""

from __future__ import annotations

from typing import List

from repro.core.fabric import FabricConfig
from repro.core.wan import Netem
from repro.scenario import (
    Scenario,
    ScenarioEvent,
    TopologySpec,
    WorkloadSpec,
    get_scenario,
    run_scenario,
)

from .common import BenchRow, timed

#: The paper's Table-1 layout as one declarative spec: no default tenant,
#: three jobs attached host by host at step 0.
TABLE1 = Scenario(
    name="table1_tenancy",
    topology=TopologySpec(fabric=FabricConfig(), default_tenant=False, seed=1),
    workload=WorkloadSpec(strategy=None, steps=0),
    events=tuple(
        ScenarioEvent(kind="tenant_attach", at_step=0, tenant=t, vni=v, host=h)
        for t, v, hosts in (
            ("job-a", 100, ("d1h1", "d1h2", "d2h1")),
            ("job-b", 200, ("d1h3", "d1h5", "d2h4")),
            ("job-c", 300, ("d1h4",)),
        )
        for h in hosts
    ),
    description="Table 1: three jobs on VNIs 100/200/300, isolation matrix.",
)


def run() -> List[BenchRow]:
    result = run_scenario(TABLE1)
    geo = result.geo
    tenancy = geo.tenancy
    netem = Netem(geo.fabric, seed=1)

    # the four rows of Table 1
    table = [
        ("d1h1", "d2h1", True),   # 100 -> 100 cross-DC: ~21.4 ms in paper
        ("d1h3", "d1h5", True),   # 200 -> 200 same-DC: ~0.07 ms
        ("d1h2", "d1h3", False),  # 100 -> 200: unreachable
        ("d1h4", "d2h4", False),  # 300 -> 200: unreachable
    ]
    rows: List[BenchRow] = []
    for src, dst, want in table:
        ok, us = timed(lambda s=src, d=dst: tenancy.ping(s, d))
        assert ok == want, (src, dst, ok, want)
        if ok:
            rtt = netem.base_rtt_ms(src, dst)
            derived = f"reachable rtt~{rtt:.2f}ms"
        else:
            derived = "destination host unreachable"
        rows.append(
            BenchRow(name=f"table1_{src}_to_{dst}", us_per_call=us, derived=derived)
        )

    _, us = timed(tenancy.verify_isolation)
    n_pairs = sum(
        len(ta.hosts) * len(tb.hosts)
        for ta in tenancy.tenants.values()
        for tb in tenancy.tenants.values()
    )
    rows.append(
        BenchRow(
            name="table1_full_isolation_matrix",
            us_per_call=us,
            derived=f"all {n_pairs} ordered pairs verified (intra ok, inter blocked)",
        )
    )
    rows.extend(_churn_rows())
    return rows


def _churn_rows() -> List[BenchRow]:
    """Multi-tenant churn through the scenario library: per-event tenant
    detach/attach on the training tenant plus the d1l3 isolation episode,
    with the control plane's incremental resync stats gated."""
    result, us = timed(lambda: run_scenario(get_scenario("multi_tenant_churn")))
    spec = result.scenario
    churn_events = [e for e in spec.events if e.kind.startswith("tenant_")]
    flap_events = [e for e in spec.events if e.kind.endswith("_link")]
    # the workload must keep syncing through every churn step
    assert len(result.steps) == spec.workload.steps
    assert all(s.sync_seconds > 0 for s in result.steps)
    # churn must not leak state: after the final re-attach + restores the
    # full isolation matrix still holds
    result.geo.tenancy.verify_isolation()
    resyncs = result.evpn_resyncs
    assert len(resyncs) == len(flap_events), (len(resyncs), len(flap_events))
    partitions = [s for s in resyncs if s.rebuilt > 0]
    rows = [
        BenchRow(
            name="tenancy_churn_scenario",
            us_per_call=us,
            derived=(
                f"{len(churn_events)} tenant churn events + "
                f"{len(flap_events)} flaps over {len(result.steps)} steps; "
                f"sync {result.mean_step_seconds:.3f}s/step; isolation matrix "
                f"clean after churn"
            ),
            metrics={"churn_mean_step_seconds": result.mean_step_seconds},
        ),
        BenchRow(
            name="tenancy_churn_evpn_resync",
            us_per_call=0.0,
            derived=(
                f"EvpnResyncStats over the churn: {len(resyncs)} resyncs, "
                f"{len(partitions)} with non-empty blast radius "
                f"(leaf-isolation episode), mean touched "
                f"{100 * result.evpn_mean_touched_frac:.1f}% of VTEPs, "
                f"total {sum(s.rebuilt for s in resyncs)} VTEP table rebuilds "
                f"+ {sum(s.patched for s in resyncs)} RIB patches"
            ),
            metrics={
                "churn_evpn_mean_touched_frac": result.evpn_mean_touched_frac,
                "churn_evpn_rebuilt_total": float(
                    sum(s.rebuilt for s in resyncs)
                ),
            },
        ),
    ]
    return rows
