"""Table 1 reproduction: VNI multi-tenancy reachability matrix.

Paper host/VNI assignment: d1h1, d1h2, d2h1 on VNI 100; d1h3, d1h5 on
VNI 200 (plus d2h4 in our richer check); d1h4 on VNI 300.  Intra-VNI
pairs ping (with RTT reflecting the WAN when cross-DC); inter-VNI pairs
get "destination host unreachable".
"""

from __future__ import annotations

from typing import List

from repro.core.evpn import EvpnControlPlane
from repro.core.fabric import Fabric
from repro.core.tenancy import TenancyManager
from repro.core.wan import Netem

from .common import BenchRow, timed


def run() -> List[BenchRow]:
    fabric = Fabric()
    evpn = EvpnControlPlane(fabric)
    tenancy = TenancyManager(fabric, evpn)
    netem = Netem(fabric, seed=1)
    tenancy.create_tenant("job-a", vni=100)
    tenancy.create_tenant("job-b", vni=200)
    tenancy.create_tenant("job-c", vni=300)
    for h in ("d1h1", "d1h2", "d2h1"):
        tenancy.attach("job-a", h)
    for h in ("d1h3", "d1h5", "d2h4"):
        tenancy.attach("job-b", h)
    tenancy.attach("job-c", "d1h4")

    # the four rows of Table 1
    table = [
        ("d1h1", "d2h1", True),   # 100 -> 100 cross-DC: ~21.4 ms in paper
        ("d1h3", "d1h5", True),   # 200 -> 200 same-DC: ~0.07 ms
        ("d1h2", "d1h3", False),  # 100 -> 200: unreachable
        ("d1h4", "d2h4", False),  # 300 -> 200: unreachable
    ]
    rows: List[BenchRow] = []
    for src, dst, want in table:
        ok, us = timed(lambda s=src, d=dst: tenancy.ping(s, d))
        assert ok == want, (src, dst, ok, want)
        if ok:
            rtt = netem.base_rtt_ms(src, dst)
            derived = f"reachable rtt~{rtt:.2f}ms"
        else:
            derived = "destination host unreachable"
        rows.append(
            BenchRow(name=f"table1_{src}_to_{dst}", us_per_call=us, derived=derived)
        )

    _, us = timed(tenancy.verify_isolation)
    n_pairs = sum(
        len(ta.hosts) * len(tb.hosts)
        for ta in tenancy.tenants.values()
        for tb in tenancy.tenants.values()
    )
    rows.append(
        BenchRow(
            name="table1_full_isolation_matrix",
            us_per_call=us,
            derived=f"all {n_pairs} ordered pairs verified (intra ok, inter blocked)",
        )
    )
    return rows
