"""§3.3.2 analytical collision model (Eqs. 3-11) vs Monte-Carlo emulation.

Reports E[C], the collision index sum(p^2), and Delta_C for baseline vs
queue-pair-aware allocation, under (a) the correlated-QP production
pathology and (b) high-entropy sequential allocation — the paper's claim
is that binning helps exactly in case (a) and is neutral in (b).
"""

from __future__ import annotations

from typing import List

from repro.core.collision import compare_schemes
from repro.core.ports import ALIASING_STRIDE

from .common import BenchRow, timed


def run() -> List[BenchRow]:
    rows: List[BenchRow] = []
    for num_qps in (4, 8, 16, 32):
        res, us = timed(
            lambda n=num_qps: compare_schemes(
                num_qps=n, num_paths=4, trials=800, qp_stride=ALIASING_STRIDE, seed=5
            )
        )
        rows.append(
            BenchRow(
                name=f"eq5_collisions_correlated_qps{num_qps}",
                us_per_call=us / 1600,
                derived=(
                    f"E[C] base={res['baseline'].mean_pairwise_collisions:.2f} "
                    f"prop={res['proposed'].mean_pairwise_collisions:.2f} "
                    f"dC_emp={res['delta_c_empirical']:+.2%} "
                    f"dC_analytic={res['delta_c_analytic']:+.2%}"
                ),
            )
        )
    res, us = timed(
        lambda: compare_schemes(num_qps=16, num_paths=4, trials=800, qp_stride=1, seed=6)
    )
    rows.append(
        BenchRow(
            name="eq11_neutral_under_entropy",
            us_per_call=us / 1600,
            derived=(
                f"sequential QPs: dC_emp={res['delta_c_empirical']:+.2%} "
                "(paper: mechanism does not improve ideal hashing)"
            ),
        )
    )
    return rows
