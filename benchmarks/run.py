"""Benchmark driver: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only fig14] [--json-dir out/]``

Prints the ``name,us_per_call,derived`` CSV contract; with ``--json-dir``
each suite additionally lands as ``BENCH_<suite>.json`` (the files CI
uploads as a workflow artifact).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys
import traceback

from .common import HEADER

SUITES = (
    ("fig8_rtt", "benchmarks.bench_rtt"),
    ("fig11_12_ecmp", "benchmarks.bench_ecmp"),
    ("eq3_11_collision", "benchmarks.bench_collision"),
    ("collectives_scale", "benchmarks.bench_collectives"),
    ("fig9_13_failover", "benchmarks.bench_failover"),
    ("table1_tenancy", "benchmarks.bench_tenancy"),
    ("fig14_training", "benchmarks.bench_training"),
    ("wan_sync_beyond_paper", "benchmarks.bench_wan_sync"),
    ("schedule_overlap", "benchmarks.bench_schedule"),
    ("scenarios", "benchmarks.bench_scenarios"),
    ("sweeps", "benchmarks.bench_sweeps"),
    ("resilience", "benchmarks.bench_resilience"),
    ("serving", "benchmarks.bench_serving"),
    ("roofline", "benchmarks.bench_roofline"),
)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None, help="substring filter on suite name")
    ap.add_argument(
        "--json-dir",
        default=None,
        help="also write one BENCH_<suite>.json per suite into this directory",
    )
    args = ap.parse_args()

    json_dir = None
    if args.json_dir:
        json_dir = pathlib.Path(args.json_dir)
        json_dir.mkdir(parents=True, exist_ok=True)

    import importlib

    print(HEADER)
    failures = []
    for name, module in SUITES:
        if args.only and args.only not in name:
            continue
        try:
            mod = importlib.import_module(module)
            rows = list(mod.run())
            for row in rows:
                print(row.csv(), flush=True)
            if json_dir is not None:
                payload = {
                    "suite": name,
                    "module": module,
                    "rows": [dataclasses.asdict(r) for r in rows],
                }
                (json_dir / f"BENCH_{name}.json").write_text(
                    json.dumps(payload, indent=2) + "\n"
                )
        except Exception as e:  # noqa: BLE001
            failures.append((name, e))
            print(f"{name},0.0,SUITE FAILED: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
            if json_dir is not None:
                (json_dir / f"BENCH_{name}.json").write_text(
                    json.dumps({"suite": name, "module": module, "error": str(e)})
                    + "\n"
                )
    if failures:
        raise SystemExit(f"{len(failures)} benchmark suites failed: {[f[0] for f in failures]}")


if __name__ == "__main__":
    main()
