"""Fig. 14 reproduction: AllReduce vs Parameter-Server geo-training of
DistilGPT2-82M over the emulated 800 Mbit/s / 22 ms WAN.

Thin wrapper over the declarative scenario library (ISSUE 5): the
topology, gradient volumes and costing options come from
``repro.scenario.library``'s ``fig14_allreduce`` / ``fig14_ps`` /
``compute_overlap`` entries — this module only adds the Fig-14 statistical
dressing (per-batch jitter, the PS server-contention band) and the gates.

Per-batch time = gradient computation + synchronization, both from the
framework itself:

* computation — measured by running the REAL 82M-parameter model (one
  fwd+bwd+AdamW step, paper batch size) on this host, then scaled by the
  paper's GPU/CPU throughput ratio (documented constant);
* synchronization — the flow-level contended congestion model over the
  routed QP flows (the scenario's ``SyncOptions(congestion=True)``: max-min
  fair shares on every link, per-flow path propagation — the same pipeline
  as the paper's testbed: ring AllReduce crosses the WAN twice; PS
  pushes+pulls through the DC1 server), with the ideal fluid estimate
  reported alongside as a per-strategy fluid-vs-contended delta row.

Paper observations to match: AllReduce ~5-11 s/batch, PS ~9-18 s/batch,
PS slower with higher variance; gradient volumes ~312 MB (AR) vs ~459 MB
(PS).

Beyond the paper (ROADMAP item, ISSUE 4): the ``compute_overlap`` scenario
sweep over overlap fractions (0, 0.25, 0.5, 0.75) through the event-driven
congestion simulator, gated on step time decreasing monotonically with the
overlap fraction — communication hidden behind backprop must never make a
step slower.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List

import numpy as np

from repro.scenario import SyncOptions, get_scenario, run_scenario
from repro.scenario.library import CALIBRATED_COMPUTE_S

from .common import BenchRow

BATCHES = 24

#: Server-side contention multiplier for PS (paper: "bandwidth saturation
#: and contention at the server node" — Ray object store + 4 concurrent
#: pushers serializing on one NIC).
PS_CONTENTION = 1.5


def measure_compute_seconds() -> float:
    """One real train step of the real 82M model on this host (smoke batch).

    Reported for transparency; the Fig-14 reproduction uses the calibrated
    ``repro.scenario.library.CALIBRATED_COMPUTE_S`` because the paper's
    trainer hardware is unspecified.
    """
    import jax

    from repro.configs import get_config
    from repro.models import init_params, loss_fn

    cfg = get_config("distilgpt2-82m")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    B, S = 2, 128
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    @jax.jit
    def step(p, b):
        (_, _), g = jax.value_and_grad(lambda q: loss_fn(q, b, cfg), has_aux=True)(p)
        return jax.tree.map(lambda a, gg: a - 1e-4 * gg.astype(a.dtype), p, g)

    step(params, batch)  # compile
    times = []
    for _ in range(3):
        t0 = time.time()
        out = step(params, batch)
        jax.tree.leaves(out)[0].block_until_ready()
        times.append(time.time() - t0)
    return float(np.median(times))


def run() -> List[BenchRow]:
    host_step_s = measure_compute_seconds()
    rows: List[BenchRow] = [
        BenchRow(
            name="fig14_host_compute_reference",
            us_per_call=host_step_s * 1e6,
            derived=f"real 82M train step on this host (2x128 tokens): {host_step_s:.2f}s; "
            f"calibrated paper-batch compute={CALIBRATED_COMPUTE_S}s",
        )
    ]
    results = {}
    geo = None
    for scenario_name in ("fig14_allreduce", "fig14_ps"):
        spec = get_scenario(scenario_name)
        strategy = spec.workload.strategy
        nbytes = spec.workload.grad_bytes
        # one warm fabric for the whole figure: both strategies and the
        # per-batch loop share the seeded jitter RNG stream, as before
        if geo is None:
            geo = spec.topology.build()
        fluid = geo.sync_cost(
            strategy, nbytes,
            options=dataclasses.replace(spec.options, congestion=False),
        )
        contended = run_scenario(spec, geo=geo).sync
        rows.append(
            BenchRow(
                name=f"fig14_{strategy}_fluid_vs_contended",
                us_per_call=float(contended.wan_seconds * 1e6),
                derived=(
                    f"fluid={fluid.wan_seconds:.2f}s "
                    f"contended={contended.wan_seconds:.2f}s "
                    f"delta={100 * (contended.wan_seconds / fluid.wan_seconds - 1):+.1f}% "
                    f"bottleneck={contended.bottleneck_link} "
                    f"{contended.bottleneck_bytes / 1e6:.0f}MB "
                    f"util={contended.bottleneck_utilization:.2f}"
                ),
                metrics={"contended_sync_seconds": contended.wan_seconds},
            )
        )
        jittered = dataclasses.replace(spec.options, jitter=True)
        times = []
        for _ in range(BATCHES):
            cost = geo.sync_cost(strategy, nbytes, options=jittered)
            if strategy == "ps":
                # stochastic queueing at the server NIC (paper: PS shows
                # the wider band)
                contention = float(np.clip(geo.netem.rng.normal(PS_CONTENTION, 0.35), 1.1, 2.4))
            else:
                contention = 1.0
            sync_s = cost.wan_seconds * contention
            # compute jitter: stragglers/input pipeline (paper shows wide bands)
            c = CALIBRATED_COMPUTE_S * float(
                np.exp(np.clip(geo.netem.rng.normal(0.3, 0.4), -0.3, 1.0))
            )
            times.append(c + sync_s)
        times = np.array(times)
        results[strategy] = times
        rows.append(
            BenchRow(
                name=f"fig14_{strategy}_per_batch_s",
                us_per_call=float(times.mean() * 1e6),
                derived=(
                    f"mean={times.mean():.1f}s min={times.min():.1f} "
                    f"max={times.max():.1f} std={times.std():.2f} "
                    f"(paper {'5-11s' if strategy == 'allreduce' else '9-18s'})"
                ),
            )
        )
    ar, ps = results["allreduce"], results["ps"]
    assert ar.mean() < ps.mean(), "paper: AllReduce faster than PS"
    assert ar.std() < ps.std() * 1.5, "paper: PS shows higher variance"
    rows.append(
        BenchRow(
            name="fig14_ar_vs_ps",
            us_per_call=0.0,
            derived=(
                f"AR/PS mean ratio={ar.mean() / ps.mean():.2f} "
                f"(paper ~0.55); PS bottleneck=server leaf links"
            ),
            metrics={
                "ar_mean_batch_seconds": float(ar.mean()),
                "ps_mean_batch_seconds": float(ps.mean()),
            },
        )
    )
    rows.extend(_overlap_sweep_rows())
    return rows


#: ROADMAP's sweep over with_compute_overlap fractions.
OVERLAP_FRACTIONS = (0.0, 0.25, 0.5, 0.75)


def _overlap_sweep_rows() -> List[BenchRow]:
    """Step time vs overlap fraction: one ``compute_overlap`` scenario per
    point, through the event-driven simulator.

    The gate demands monotonically non-increasing step times — exposing
    more of the sync behind backprop can only help — and a strict
    end-to-end win since this workload's comm exceeds compute at every
    fraction.
    """
    steps = {}
    for frac in OVERLAP_FRACTIONS:
        spec = get_scenario("compute_overlap", overlap_fraction=frac)
        # jitter-free sweep: every point is a deterministic spec evaluation
        spec = dataclasses.replace(spec, options=SyncOptions(jitter=False, congestion=True))
        steps[frac] = run_scenario(spec).steps[0].seconds
    for lo, hi in zip(OVERLAP_FRACTIONS, OVERLAP_FRACTIONS[1:]):
        if steps[hi] > steps[lo] + 1e-9:
            raise AssertionError(
                f"step time must not grow with overlap: f={lo} -> "
                f"{steps[lo]:.3f}s but f={hi} -> {steps[hi]:.3f}s"
            )
    if not steps[OVERLAP_FRACTIONS[-1]] < steps[0]:
        raise AssertionError(
            "comm exceeds compute here, so 75% overlap must strictly beat 0%"
        )
    return [
        BenchRow(
            name="fig14_overlap_sweep",
            us_per_call=float(steps[OVERLAP_FRACTIONS[-1]] * 1e6),
            derived=" ".join(
                f"f={frac}:{steps[frac]:.2f}s" for frac in OVERLAP_FRACTIONS
            )
            + " (monotone non-increasing gate)",
            metrics={
                f"step_f{int(frac * 100):02d}_seconds": steps[frac]
                for frac in OVERLAP_FRACTIONS
            },
        )
    ]
