"""Fig. 14 reproduction: AllReduce vs Parameter-Server geo-training of
DistilGPT2-82M over the emulated 800 Mbit/s / 22 ms WAN.

Per-batch time = gradient computation + synchronization, both from the
framework itself:

* computation — measured by running the REAL 82M-parameter model (one
  fwd+bwd+AdamW step, paper batch size) on this host, then scaled by the
  paper's GPU/CPU throughput ratio (documented constant);
* synchronization — the flow-level contended congestion model over the
  routed QP flows (``sync_cost(congestion=True)``: max-min fair shares on
  every link, per-flow path propagation — the same pipeline as the paper's
  testbed: ring AllReduce crosses the WAN twice; PS pushes+pulls through
  the DC1 server), with the ideal fluid estimate reported alongside as a
  per-strategy fluid-vs-contended delta row.

Paper observations to match: AllReduce ~5-11 s/batch, PS ~9-18 s/batch,
PS slower with higher variance; gradient volumes ~312 MB (AR) vs ~459 MB
(PS).

Beyond the paper (ROADMAP item, ISSUE 4): a schedule-aware sweep over
``with_compute_overlap`` fractions (0, 0.25, 0.5, 0.75) through the
event-driven congestion simulator, gated on step time decreasing
monotonically with the overlap fraction — communication hidden behind
backprop must never make a step slower.
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core.geo import GeoFabric

from .common import BenchRow

#: DistilGPT2 fp32 gradient volume (paper: ~312 MB with DDP).
AR_GRAD_BYTES = 312_000_000
#: PS per-batch volume (paper: ~459 MB: fp32 grads + momentum-carrying pulls).
PS_GRAD_BYTES = 459_000_000
BATCHES = 24


#: Per-batch gradient-computation floor calibrated to Fig. 14: the paper's
#: AllReduce minimum (~5 s) minus the modeled minimum sync time (~3.4 s)
#: gives ~1.6-2.5 s of compute on their (unspecified) trainer hardware; we
#: use 2.2 s with wide multiplicative jitter matching their bands.
CALIBRATED_COMPUTE_S = 2.2
#: Server-side contention multiplier for PS (paper: "bandwidth saturation
#: and contention at the server node" — Ray object store + 4 concurrent
#: pushers serializing on one NIC).
PS_CONTENTION = 1.5


def measure_compute_seconds() -> float:
    """One real train step of the real 82M model on this host (smoke batch).

    Reported for transparency; the Fig-14 reproduction uses the calibrated
    constant above because the paper's trainer hardware is unspecified.
    """
    import jax

    from repro.configs import get_config
    from repro.models import init_params, loss_fn

    cfg = get_config("distilgpt2-82m")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    B, S = 2, 128
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    @jax.jit
    def step(p, b):
        (_, _), g = jax.value_and_grad(lambda q: loss_fn(q, b, cfg), has_aux=True)(p)
        return jax.tree.map(lambda a, gg: a - 1e-4 * gg.astype(a.dtype), p, g)

    step(params, batch)  # compile
    times = []
    for _ in range(3):
        t0 = time.time()
        out = step(params, batch)
        jax.tree.leaves(out)[0].block_until_ready()
        times.append(time.time() - t0)
    return float(np.median(times))


def run() -> List[BenchRow]:
    geo = GeoFabric(num_pods=2, workers_per_pod=2, num_channels=4, seed=14)
    host_step_s = measure_compute_seconds()
    rows: List[BenchRow] = [
        BenchRow(
            name="fig14_host_compute_reference",
            us_per_call=host_step_s * 1e6,
            derived=f"real 82M train step on this host (2x128 tokens): {host_step_s:.2f}s; "
            f"calibrated paper-batch compute={CALIBRATED_COMPUTE_S}s",
        )
    ]
    results = {}
    for strategy, nbytes in (("allreduce", AR_GRAD_BYTES), ("ps", PS_GRAD_BYTES)):
        fluid = geo.sync_cost(strategy, nbytes, jitter=False)
        contended = geo.sync_cost(strategy, nbytes, jitter=False, congestion=True)
        rows.append(
            BenchRow(
                name=f"fig14_{strategy}_fluid_vs_contended",
                us_per_call=float(contended.wan_seconds * 1e6),
                derived=(
                    f"fluid={fluid.wan_seconds:.2f}s "
                    f"contended={contended.wan_seconds:.2f}s "
                    f"delta={100 * (contended.wan_seconds / fluid.wan_seconds - 1):+.1f}% "
                    f"bottleneck={contended.bottleneck_link} "
                    f"{contended.bottleneck_bytes / 1e6:.0f}MB "
                    f"util={contended.bottleneck_utilization:.2f}"
                ),
                metrics={"contended_sync_seconds": contended.wan_seconds},
            )
        )
        times = []
        for _ in range(BATCHES):
            cost = geo.sync_cost(strategy, nbytes, jitter=True, congestion=True)
            if strategy == "ps":
                # stochastic queueing at the server NIC (paper: PS shows
                # the wider band)
                contention = float(np.clip(geo.netem.rng.normal(PS_CONTENTION, 0.35), 1.1, 2.4))
            else:
                contention = 1.0
            sync_s = cost.wan_seconds * contention
            # compute jitter: stragglers/input pipeline (paper shows wide bands)
            c = CALIBRATED_COMPUTE_S * float(
                np.exp(np.clip(geo.netem.rng.normal(0.3, 0.4), -0.3, 1.0))
            )
            times.append(c + sync_s)
        times = np.array(times)
        results[strategy] = times
        rows.append(
            BenchRow(
                name=f"fig14_{strategy}_per_batch_s",
                us_per_call=float(times.mean() * 1e6),
                derived=(
                    f"mean={times.mean():.1f}s min={times.min():.1f} "
                    f"max={times.max():.1f} std={times.std():.2f} "
                    f"(paper {'5-11s' if strategy == 'allreduce' else '9-18s'})"
                ),
            )
        )
    ar, ps = results["allreduce"], results["ps"]
    assert ar.mean() < ps.mean(), "paper: AllReduce faster than PS"
    assert ar.std() < ps.std() * 1.5, "paper: PS shows higher variance"
    rows.append(
        BenchRow(
            name="fig14_ar_vs_ps",
            us_per_call=0.0,
            derived=(
                f"AR/PS mean ratio={ar.mean() / ps.mean():.2f} "
                f"(paper ~0.55); PS bottleneck=server leaf links"
            ),
            metrics={
                "ar_mean_batch_seconds": float(ar.mean()),
                "ps_mean_batch_seconds": float(ps.mean()),
            },
        )
    )
    rows.extend(_overlap_sweep_rows(geo))
    return rows


#: ROADMAP's sweep over with_compute_overlap fractions.
OVERLAP_FRACTIONS = (0.0, 0.25, 0.5, 0.75)


def _overlap_sweep_rows(geo: GeoFabric) -> List[BenchRow]:
    """Step time vs overlap fraction through the event-driven simulator.

    The schedule is the flat AllReduce grafted with the calibrated compute
    phase (``with_compute_overlap`` DAG structure, not the old scalar
    discount); the gate demands monotonically non-increasing step times —
    exposing more of the sync behind backprop can only help — and a strict
    end-to-end win since this workload's comm exceeds compute at every
    fraction.
    """
    steps = {
        frac: geo.step_time(
            "allreduce",
            AR_GRAD_BYTES,
            CALIBRATED_COMPUTE_S,
            overlap_fraction=frac,
            jitter=False,
            congestion=True,
        )
        for frac in OVERLAP_FRACTIONS
    }
    for lo, hi in zip(OVERLAP_FRACTIONS, OVERLAP_FRACTIONS[1:]):
        if steps[hi] > steps[lo] + 1e-9:
            raise AssertionError(
                f"step time must not grow with overlap: f={lo} -> "
                f"{steps[lo]:.3f}s but f={hi} -> {steps[hi]:.3f}s"
            )
    if not steps[OVERLAP_FRACTIONS[-1]] < steps[0]:
        raise AssertionError(
            "comm exceeds compute here, so 75% overlap must strictly beat 0%"
        )
    return [
        BenchRow(
            name="fig14_overlap_sweep",
            us_per_call=float(steps[OVERLAP_FRACTIONS[-1]] * 1e6),
            derived=" ".join(
                f"f={frac}:{steps[frac]:.2f}s" for frac in OVERLAP_FRACTIONS
            )
            + " (monotone non-increasing gate)",
            metrics={
                f"step_f{int(frac * 100):02d}_seconds": steps[frac]
                for frac in OVERLAP_FRACTIONS
            },
        )
    ]
