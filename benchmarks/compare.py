"""Bench-baseline regression gate for CI (``python -m benchmarks.compare``).

Compares the ``BENCH_<suite>.json`` files produced by
``python -m benchmarks.run --json-dir <new>`` against the committed
snapshots in ``benchmarks/baselines/`` and **fails (exit 1) when any gated
metric regresses more than ``--threshold`` (default 20%)** — the CI
tripwire that keeps model-quality observables (modeled WAN seconds, load
factors, effective-throughput Mbit/s, EVPN resync blast radius) from
silently drifting as the simulator evolves.

What is gated: only the ``metrics`` dict of each ``BenchRow`` (see
``benchmarks/common.py``).  Sweep/campaign artifacts
(``repro.scenario.sweep.SweepResult.to_dict()``) gate the same way: their
``variants`` list is read exactly like a suite's ``rows``, one entry per
campaign variant.  Wall-clock fields (``us_per_call``) are never
gated — they measure the runner, not the model.  Direction is inferred
from the metric name by :func:`metric_direction`:

* ``*_gbps``, ``*_mbps``, ``*_speedup``, ``*_improvement_pct`` — higher is
  better (a >threshold drop regresses);
* ``*_s``, ``*_ms``, ``*_seconds``, ``*_factor``, ``*_frac``, ``*_bytes``,
  and the latency-percentile suffixes ``*_p50``/``*_p99`` — lower is
  better (a >threshold rise regresses);
* anything else — treated as a pinned reproducibility observable: a
  >threshold move in *either* direction regresses.

A suite present in the baseline but missing (or errored) in the new run
fails, and so does any individual baseline (row, metric) pair the new run
no longer reports — renaming a row or dropping a gated metric cannot
silently disable its gate.  New suites/rows/metrics with no baseline pass
silently — commit a refreshed baseline to start gating them.

A markdown delta table goes to stdout and, with ``--summary FILE``
(pointed at ``$GITHUB_STEP_SUMMARY`` in CI), to the job summary.

Refreshing baselines after an intentional model change::

    PYTHONPATH=src python -m benchmarks.run --json-dir benchmarks/baselines
"""

from __future__ import annotations

import argparse
import json
import math
import pathlib
import sys
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple

HIGHER_IS_BETTER_SUFFIXES = ("_gbps", "_mbps", "_speedup", "_improvement_pct")
LOWER_IS_BETTER_SUFFIXES = (
    "_s", "_ms", "_seconds", "_factor", "_frac", "_bytes", "_p50", "_p99",
)


def metric_direction(name: str) -> str:
    """``"higher"`` | ``"lower"`` | ``"pinned"`` — which way is *better*."""
    if name.endswith(HIGHER_IS_BETTER_SUFFIXES):
        return "higher"
    if name.endswith(LOWER_IS_BETTER_SUFFIXES):
        return "lower"
    return "pinned"


@dataclass(frozen=True)
class Delta:
    """One (suite, row, metric) comparison against its baseline."""

    suite: str
    row: str
    metric: str
    baseline: float
    new: float
    direction: str

    @property
    def change_frac(self) -> float:
        """Signed relative change vs baseline (+0.25 = 25% higher)."""
        if self.baseline == 0.0:
            return 0.0 if self.new == 0.0 else math.inf
        return (self.new - self.baseline) / abs(self.baseline)

    def regressed(self, threshold: float) -> bool:
        c = self.change_frac
        if self.direction == "higher":
            return c < -threshold
        if self.direction == "lower":
            return c > threshold
        return abs(c) > threshold


def _load_suite(path: pathlib.Path) -> dict:
    return json.loads(path.read_text())


def _row_metrics(payload: dict) -> Dict[Tuple[str, str], float]:
    """Gated (row, metric) pairs of one suite *or* campaign payload.

    Two shapes are accepted: the ``BenchRow`` dump of ``benchmarks/run.py``
    (``rows``) and the joined result table of a sweep/Monte Carlo campaign
    (``repro.scenario.sweep.SweepResult.to_dict()``, ``variants`` — one
    BenchRow-shaped entry per variant), so committed campaign artifacts
    regression-gate exactly like hand-written suites.
    """
    out: Dict[Tuple[str, str], float] = {}
    for row in list(payload.get("rows", ())) + list(payload.get("variants", ())):
        for metric, value in (row.get("metrics") or {}).items():
            out[(row["name"], metric)] = float(value)
    return out


def iter_deltas(
    baseline_dir: pathlib.Path, new_dir: pathlib.Path
) -> Iterator[Tuple[str, Optional[str], List[Delta], List[Tuple[str, str]]]]:
    """Yield ``(suite, error, deltas, missing)`` per baseline suite.

    ``error`` is non-None when the new run is missing or errored, and
    ``missing`` lists baseline (row, metric) pairs the new run no longer
    reports — both are automatic regressions regardless of metric values
    (dropping a gated metric must not silently disable its gate).
    """
    for base_path in sorted(baseline_dir.glob("BENCH_*.json")):
        suite = base_path.stem[len("BENCH_") :]
        base = _load_suite(base_path)
        new_path = new_dir / base_path.name
        if not new_path.exists():
            yield suite, f"suite missing from {new_dir}", [], []
            continue
        new = _load_suite(new_path)
        if "error" in new:
            yield suite, f"suite errored: {new['error']}", [], []
            continue
        base_metrics = _row_metrics(base)
        new_metrics = _row_metrics(new)
        deltas = [
            Delta(
                suite=suite,
                row=row,
                metric=metric,
                baseline=value,
                new=new_metrics[(row, metric)],
                direction=metric_direction(metric),
            )
            for (row, metric), value in sorted(base_metrics.items())
            if (row, metric) in new_metrics
        ]
        missing = sorted(set(base_metrics) - set(new_metrics))
        yield suite, None, deltas, missing


def render_table(
    results: List[Tuple[str, Optional[str], List[Delta], List[Tuple[str, str]]]],
    threshold: float,
) -> str:
    lines = [
        "## Bench baseline comparison",
        "",
        f"Gate: any gated metric regressing > {threshold:.0%} vs "
        "`benchmarks/baselines/` fails.",
        "",
        "| suite | row | metric | baseline | new | change | gate |",
        "|---|---|---|---|---|---|---|",
    ]
    for suite, error, deltas, missing in results:
        if error is not None:
            lines.append(f"| {suite} | — | — | — | — | — | FAIL ({error}) |")
            continue
        for d in deltas:
            bad = d.regressed(threshold)
            arrow = {"higher": "↑ better", "lower": "↓ better", "pinned": "pinned"}
            lines.append(
                f"| {d.suite} | {d.row} | {d.metric} ({arrow[d.direction]}) "
                f"| {d.baseline:.6g} | {d.new:.6g} "
                f"| {d.change_frac:+.1%} | {'**FAIL**' if bad else 'ok'} |"
            )
        for row, metric in missing:
            lines.append(
                f"| {suite} | {row} | {metric} | — | *missing* | — "
                f"| **FAIL** (gated metric dropped) |"
            )
    return "\n".join(lines) + "\n"


def compare(
    baseline_dir: pathlib.Path,
    new_dir: pathlib.Path,
    threshold: float = 0.20,
) -> Tuple[str, List[str]]:
    """Returns (markdown table, list of regression descriptions)."""
    results = list(iter_deltas(baseline_dir, new_dir))
    regressions: List[str] = []
    for suite, error, deltas, missing in results:
        if error is not None:
            regressions.append(f"{suite}: {error}")
        for d in deltas:
            if d.regressed(threshold):
                regressions.append(
                    f"{suite}/{d.row}/{d.metric}: {d.baseline:.6g} -> "
                    f"{d.new:.6g} ({d.change_frac:+.1%}, {d.direction} is better)"
                )
        for row, metric in missing:
            regressions.append(
                f"{suite}/{row}/{metric}: gated metric missing from the new "
                "run (renamed row or dropped metric disables its gate)"
            )
    return render_table(results, threshold), regressions


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument(
        "--baseline",
        default="benchmarks/baselines",
        help="directory of committed BENCH_*.json snapshots",
    )
    ap.add_argument(
        "--new", dest="new_dir", required=True,
        help="directory of freshly produced BENCH_*.json files",
    )
    ap.add_argument(
        "--threshold", type=float, default=0.20,
        help="relative regression tolerance (default 0.20 = 20%%)",
    )
    ap.add_argument(
        "--summary", default=None,
        help="append the markdown delta table to this file "
        "(point at $GITHUB_STEP_SUMMARY in CI)",
    )
    args = ap.parse_args(argv)
    table, regressions = compare(
        pathlib.Path(args.baseline), pathlib.Path(args.new_dir), args.threshold
    )
    print(table)
    if args.summary:
        with open(args.summary, "a") as fh:
            fh.write(table + "\n")
    if regressions:
        print(
            f"{len(regressions)} gated metric(s) regressed beyond "
            f"{args.threshold:.0%}:",
            file=sys.stderr,
        )
        for r in regressions:
            print(f"  - {r}", file=sys.stderr)
        return 1
    print("All gated metrics within tolerance.")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
