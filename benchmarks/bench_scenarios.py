"""Scenario-library suite: every named scenario, executed and gated.

ISSUE 5 satellite: runs each entry of ``repro.scenario.library`` through
``run_scenario`` and emits its deterministic ``ScenarioResult.metrics()``
as gated ``BenchRow.metrics`` — wired into ``benchmarks/run.py`` and the
``benchmarks/compare.py`` baseline gate (``BENCH_scenarios.json``), so a
regression in any library study fails CI exactly like the hand-written
suites.

Cross-scenario gates (the study conclusions, not just the numbers):

* ``rs_ag_overlap`` strictly beats ``rs_then_ag`` (pipelining wins on
  shared WAN bottlenecks);
* the ``compute_overlap`` sweep is monotone non-increasing in the overlap
  fraction;
* ``ecmp_collision``: at the paper's sensitive 4-channel regime the
  ``qp_aware`` allocator prices strictly below ``baseline`` under the
  ECMP-weighted congestion model;
* ``bfd_flap_storm`` / ``multi_tenant_churn``: every flap produces a
  recovery timeline / EVPN resync record, and recovery stays in the BFD
  class (~110 ms), not the BGP class.

ISSUE 9 adds the allocator gates:

* **library equivalence** — representative multi-phase scenarios re-run
  with the from-scratch :class:`_FullEpochAllocator`
  (``INCREMENTAL_EVENT_LOOP = False``) must reproduce the incremental
  run's ``ScenarioResult.metrics()`` *exactly* (dict equality, no
  tolerance) — the repo's byte-identity-gate convention
  (``docs/ARCHITECTURE.md``) applied to the event loop;
* **SCALED64** (:mod:`benchmarks.scaled64`) — the 64-DC / ~100k-flow
  leader-ring schedule replayed through ``_simulate_events`` with both
  allocators: per-flow timelines and per-link peak throughput must be
  byte-identical, and the incremental event loop must be >=
  ``MIN_EVENT_LOOP_SPEEDUP``x faster wall-clock (assertion, like the
  batched-router gate — wall-clock is never a compared metric).
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import congestion
from repro.scenario import ScenarioResult, get_scenario, run_scenario, scenario_names

from .common import BenchRow, timed

OVERLAP_FRACTIONS = (0.0, 0.5)  # the full sweep is gated in fig14_training

#: Multi-phase library scenarios re-run under the from-scratch allocator
#: for the exact-equality gate (cheap ones — the gate is about identity,
#: not coverage; the property test covers random DAG shapes).
EQUIVALENCE_SCENARIOS = ("rs_ag_overlap", "serving_under_flap")

MIN_EVENT_LOOP_SPEEDUP = 5.0


def _row(name: str, result: ScenarioResult, us: float) -> BenchRow:
    bits = [f"{len(result.steps)} steps"]
    if result.sync is not None:
        bits.append(f"sync={result.sync.wan_seconds:.3f}s")
    if result.recoveries:
        bits.append(f"{len(result.recoveries)} recoveries")
    if result.evpn_resyncs:
        bits.append(
            f"evpn touched {100 * result.evpn_mean_touched_frac:.1f}%"
        )
    return BenchRow(
        name=f"scenario_{name}",
        us_per_call=us,
        derived=" ".join(bits) + f" | {result.scenario.description[:60]}",
        metrics=result.metrics(),
    )


def run() -> List[BenchRow]:
    rows: List[BenchRow] = []
    results: Dict[str, ScenarioResult] = {}
    for name in scenario_names():
        if name == "compute_overlap":
            for frac in OVERLAP_FRACTIONS:
                key = f"compute_overlap_f{int(frac * 100):02d}"
                results[key], us = timed(
                    lambda f=frac: run_scenario(
                        get_scenario("compute_overlap", overlap_fraction=f)
                    )
                )
                rows.append(_row(key, results[key], us))
        elif name == "ecmp_collision":
            for scheme in ("baseline", "qp_aware"):
                key = f"ecmp_collision_{scheme}"
                results[key], us = timed(
                    lambda s=scheme: run_scenario(
                        get_scenario("ecmp_collision", port_scheme=s)
                    )
                )
                rows.append(_row(key, results[key], us))
        else:
            results[name], us = timed(
                lambda n=name: run_scenario(get_scenario(n))
            )
            rows.append(_row(name, results[name], us))

    # -- study-conclusion gates ----------------------------------------------
    overlap = results["rs_ag_overlap"].sync.wan_seconds
    serial = results["rs_then_ag"].sync.wan_seconds
    if not overlap < serial:
        raise AssertionError(
            f"rs_ag_overlap ({overlap:.3f}s) must beat rs_then_ag ({serial:.3f}s)"
        )
    f0 = results["compute_overlap_f00"].steps[0].seconds
    f50 = results["compute_overlap_f50"].steps[0].seconds
    if f50 > f0 + 1e-9:
        raise AssertionError(f"overlap must not slow steps: f=0 {f0:.3f}s f=0.5 {f50:.3f}s")
    base = results["ecmp_collision_baseline"].sync.wan_seconds
    qp = results["ecmp_collision_qp_aware"].sync.wan_seconds
    if not qp < base:
        raise AssertionError(
            f"qp_aware ({qp:.3f}s) must price below baseline ({base:.3f}s) "
            "at the 4-channel collision regime"
        )
    storm = results["bfd_flap_storm"]
    n_fail = sum(
        1 for e in storm.scenario.events if e.kind == "fail_link"
    )
    if len(storm.recoveries) != n_fail:
        raise AssertionError("every storm failure must produce a recovery timeline")
    mean_rec = sum(t.recovery_ms for t in storm.recoveries) / len(storm.recoveries)
    if not mean_rec < 1000.0:
        raise AssertionError(f"BFD-class recovery expected, got {mean_rec:.0f}ms")
    churn = results["multi_tenant_churn"]
    if not churn.evpn_resyncs:
        raise AssertionError("churn scenario must surface EvpnResyncStats")
    # -- allocator gates (ISSUE 9) -------------------------------------------
    # library equivalence: from-scratch oracle reproduces the incremental
    # run's metrics exactly
    assert congestion.INCREMENTAL_EVENT_LOOP, "bench assumes incremental default"
    congestion.INCREMENTAL_EVENT_LOOP = False
    try:
        for name in EQUIVALENCE_SCENARIOS:
            full = run_scenario(get_scenario(name))
            if full.metrics() != results[name].metrics():
                raise AssertionError(
                    f"scenario {name!r}: from-scratch allocator metrics "
                    "diverge from incremental run"
                )
    finally:
        congestion.INCREMENTAL_EVENT_LOOP = True

    # SCALED64: byte-identity + wall-clock speedup of the event loop itself
    from .scaled64 import build_scaled64

    fabric64, netem64, sched64 = build_scaled64()
    flows64 = sched64.all_flows()
    nb64 = np.asarray([f.nbytes for f in flows64], dtype=np.float64)
    slices64 = sched64.flow_slices()
    fabric64.reset_counters()
    _, paths64 = fabric64.route_flows_with_paths(flows64)
    matrix64 = congestion.build_link_load_matrix(fabric64, netem64, paths64)
    link_total64 = np.bincount(
        matrix64.mem_link,
        weights=nb64[matrix64.mem_flow],
        minlength=len(matrix64.links),
    )
    rep_inc, inc_us = timed(
        lambda: congestion._simulate_events(
            sched64, matrix64, nb64, slices64, link_total64, incremental=True
        )
    )
    rep_full, full_us = timed(
        lambda: congestion._simulate_events(
            sched64, matrix64, nb64, slices64, link_total64, incremental=False
        )
    )
    identical = (
        np.array_equal(rep_inc.flow_start_s, rep_full.flow_start_s)
        and np.array_equal(rep_inc.flow_drain_s, rep_full.flow_drain_s)
        and np.array_equal(rep_inc.completion_s, rep_full.completion_s)
        and np.array_equal(
            rep_inc.peak_throughput_gbps, rep_full.peak_throughput_gbps
        )
        and all(
            a.start_s == b.start_s and a.end_s == b.end_s
            for a, b in zip(rep_inc.phase_timings, rep_full.phase_timings)
        )
    )
    if not identical:
        raise AssertionError(
            "SCALED64: incremental event loop diverged from the "
            "from-scratch oracle"
        )
    speedup = full_us / inc_us
    if speedup < MIN_EVENT_LOOP_SPEEDUP:
        raise AssertionError(
            f"SCALED64 event-loop speedup {speedup:.1f}x below "
            f"{MIN_EVENT_LOOP_SPEEDUP:.0f}x target"
        )
    rows.append(
        BenchRow(
            name="scenario_scaled64_event_loop",
            us_per_call=inc_us,
            derived=(
                f"{len(flows64)} flows, {len(sched64.phases)} rounds | "
                f"incremental {inc_us / 1e6:.2f}s vs full "
                f"{full_us / 1e6:.2f}s = {speedup:.1f}x (byte-identical; "
                f"target >={MIN_EVENT_LOOP_SPEEDUP:.0f}x) | "
                f"makespan {rep_inc.seconds:.3f}s"
            ),
            metrics={
                "scaled64_makespan_seconds": rep_inc.seconds,
                "scaled64_peak_wan_gbps": rep_inc.effective_wan_gbps,
            },
        )
    )

    rows.append(
        BenchRow(
            name="scenario_gates",
            us_per_call=0.0,
            derived=(
                f"overlap {overlap:.3f}<{serial:.3f} serial | overlap sweep "
                f"monotone ({f0:.2f}->{f50:.2f}s) | ecmp qp_aware {qp:.3f}"
                f"<{base:.3f} baseline | storm mean recovery {mean_rec:.0f}ms "
                f"(BFD class) | churn resyncs {len(churn.evpn_resyncs)}"
            ),
            metrics={
                "overlap_vs_serial_ratio": overlap / serial,
                "ecmp_qp_aware_vs_baseline_ratio": qp / base,
            },
        )
    )
    return rows
