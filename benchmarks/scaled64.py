"""SCALED64 topology tier: 64 DCs, ~100k concurrent WAN flows (ISSUE 9).

The regime "I've Got 99 Problems But FLOPS Ain't One" (PAPERS.md) argues
is where networking dominates geo-training: 64 data centers, a ring of
per-DC leaders, and enough concurrent collective rounds that ~100k flows
are in flight at once.  This module builds that workload once so both
bench suites share it:

* ``bench_collectives.py`` routes it through the 64-DC fabric (the
  topology-scale row);
* ``bench_scenarios.py`` replays it through ``simulate_schedule``'s event
  loop twice — warm-started :class:`_IncrementalAllocator` vs from-scratch
  :class:`_FullEpochAllocator` — gating byte-identity and the >=5x
  wall-clock speedup.

Every ring pair gets its *own* WAN bandwidth (a deterministic spread over
0.5-0.8 Gbit/s, the paper's effective-WAN band) so each pair drains at its
own time: the event loop sees ~one drain event per pair per round, and
because the pairs' directed WAN paths share no link, each event dirties
exactly one allocator component out of 64 — the shape the incremental
re-solve exists for.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from repro.core.fabric import Fabric, FabricConfig
from repro.core.flows import Flow, ring_allreduce_flows
from repro.core.schedule import CollectiveSchedule, Phase
from repro.core.wan import Netem, NetemProfile

#: 64 DCs x 2 spines x 2 leaves x 2 hosts/leaf = 256 hosts.
NUM_DCS = 64
SCALED64 = FabricConfig(
    num_dcs=NUM_DCS,
    spines_per_dc=2,
    leaves_per_dc=2,
    hosts_per_leaf=tuple(tuple(2 for _ in range(2)) for _ in range(NUM_DCS)),
)

#: 6 concurrent ring rounds x 64 pairs x 256 channels = 98 304 flows.
NUM_ROUNDS = 6
NUM_CHANNELS = 256
GRAD_BYTES = 48_000_000


def wan_pair_profiles() -> Dict[Tuple[int, int], NetemProfile]:
    """Distinct per-ring-pair WAN bandwidths (deterministic 0.5-0.8 Gbit/s
    spread) so every pair is its own bottleneck level and drain event."""
    pairs: Dict[Tuple[int, int], NetemProfile] = {}
    for i in range(1, NUM_DCS + 1):
        j = i % NUM_DCS + 1
        bw = 0.5 + 0.3 * ((i * 7) % 13) / 13.0
        pairs[(i, j)] = NetemProfile(
            delay_ms=5.0, jitter_ms=1.0, bandwidth_gbps=bw, loss=0.0
        )
    return pairs


def leader_ring(fabric: Fabric) -> List[str]:
    """One leader host per DC, in DC order (the DCI ring endpoints)."""
    by_dc: Dict[int, List[str]] = {}
    for name, h in fabric.hosts.items():
        by_dc.setdefault(h.dc, []).append(name)
    return [sorted(by_dc[dc])[0] for dc in sorted(by_dc)]


def build_scaled64() -> Tuple[Fabric, Netem, CollectiveSchedule]:
    """The SCALED64 fabric, per-pair netem, and ~100k-flow schedule."""
    fabric = Fabric(SCALED64)
    netem = Netem(fabric, wan_pairs=wan_pair_profiles())
    leaders = leader_ring(fabric)
    phases = []
    for p in range(NUM_ROUNDS):
        # +p*1_000_003 bytes de-synchronizes the rounds' drain times;
        # disjoint QPN spans keep the rounds' flows distinct five-tuples
        flows: List[Flow] = ring_allreduce_flows(
            leaders,
            GRAD_BYTES + p * 1_000_003,
            num_channels=NUM_CHANNELS,
            base_qpn=0x11 + p * NUM_CHANNELS * NUM_DCS * 2,
        )
        phases.append(Phase(name=f"round{p}", flows=tuple(flows), deps=()))
    return fabric, netem, CollectiveSchedule(
        name="scaled64_ring", phases=tuple(phases)
    )
