"""Beyond-paper WAN sync strategies (EXPERIMENTS.md §Perf).

Extends Fig. 14 with every schedule strategy in the
:func:`repro.core.schedule.register_strategy` registry: the paper set
(hierarchical pod-leader sync, int8-compressed WAN hops, DiLoCo-style
local SGD) plus the phased/overlapped schedules (PS push-then-pull,
pipelined RS+AG, flat and hierarchical MoE all-to-all) — same fabric,
same gradient volume, so the numbers compose directly with the Fig. 14
baselines.  Multi-phase strategies report their per-phase timeline.
"""

from __future__ import annotations

from typing import List

import numpy as np

from repro.core.geo import GeoFabric
from repro.core.schedule import strategy_names

from .common import BenchRow, timed

GRAD_BYTES = 312_000_000


def run() -> List[BenchRow]:
    geo = GeoFabric(num_pods=2, workers_per_pod=2, num_channels=4, seed=3)
    rows: List[BenchRow] = []
    base = None
    for strategy in strategy_names():
        cost, us = timed(lambda s=strategy: geo.sync_cost(s, GRAD_BYTES, jitter=False))
        if strategy == "allreduce":
            base = cost.amortized_seconds
        speedup = base / cost.amortized_seconds if cost.amortized_seconds > 0 else float("inf")
        phased = (
            " phases[" + " ".join(
                f"{p.name}={p.duration_s:.2f}s" for p in cost.phases
            ) + "]"
            if len(cost.phases) > 1
            else ""
        )
        rows.append(
            BenchRow(
                name=f"wan_sync_{strategy}",
                us_per_call=us,
                derived=(
                    f"wan={cost.wan_seconds:.2f}s amortized={cost.amortized_seconds:.3f}s "
                    f"wan_bytes={cost.wan_bytes / 1e6:.0f}MB "
                    f"speedup_vs_allreduce={speedup:.1f}x"
                    + phased
                ),
            )
        )
    # port-scheme sensitivity on the hier path: Algorithm 1 applied to the
    # cross-DC gradient flows, under the correlated-QP pathology, averaged
    # over many connection setups (single trials are hash noise).
    from repro.core.flows import hierarchical_flows, route_flows
    from repro.core.metrics import load_factor
    from repro.core.ports import ALIASING_STRIDE_STRONG

    rng = np.random.default_rng(0)
    g2 = GeoFabric(num_pods=2, workers_per_pod=2, seed=3)
    shard = GRAD_BYTES // 2
    lf = {"baseline": [], "qp_aware": []}
    wan_max = {"baseline": [], "qp_aware": []}
    for _ in range(100):
        base = int(rng.integers(0, 2**31))
        for scheme in ("baseline", "qp_aware"):
            flows = hierarchical_flows(
                g2.pod_leaders(), shard, num_channels=8, scheme=scheme,
                base_qpn=base, qp_stride=ALIASING_STRIDE_STRONG,
            )
            link_bytes = route_flows(g2.fabric, flows)
            wan = {k: v for k, v in link_bytes.items() if g2.fabric.is_wan_link(*k)}
            for link in g2.fabric.wan_links:
                u, v = sorted(link)
                wan.setdefault((u, v), 0)
                wan.setdefault((v, u), 0)
            lf[scheme].append(load_factor(wan, threshold=-1).load_factor)
            wan_max[scheme].append(max(wan.values()))
    for scheme in ("baseline", "qp_aware"):
        rows.append(
            BenchRow(
                name=f"wan_sync_hier_ports_{scheme}",
                us_per_call=0.0,
                derived=(
                    f"wan_load_factor={np.mean(lf[scheme]):.3f} "
                    f"bottleneck_bytes={np.mean(wan_max[scheme]) / 1e6:.0f}MB"
                ),
            )
        )
    rows.append(
        BenchRow(
            name="wan_sync_hier_ports_gain",
            us_per_call=0.0,
            derived=(
                f"Algorithm 1 cuts the WAN bottleneck link by "
                f"{100 * (1 - np.mean(wan_max['qp_aware']) / np.mean(wan_max['baseline'])):.1f}% "
                f"under correlated QPs (8 channels, 4 WAN paths)"
            ),
        )
    )
    return rows
