"""Figs. 11/12 reproduction: ECMP load factor vs QP count, default RXE
hashing vs the 4-bin queue-pair-aware allocation (Algorithm 1), measured
at the leaf uplinks and the spine WAN links of the emulated fabric.

Paper: peak improvement 13.7% at the leaf (16 QPs) and 9.9% at the spine
(4 QPs); the gain shrinks as QP count grows (natural entropy).  Traffic:
many flows from d1h1 to d2h2 (crossing leaf ECMP then spine WAN ECMP),
QP numbers drawn with the correlated-allocation pathology of §3.3.

ISSUE 4: the hash imbalance is now also *costed* — the weighted
congestion model turns each trial's recorded hash-slot collisions into
allocation weights, so hash collisions show up as completion-time
inflation, closing the loop between the paper's load-factor observable
and its step-time consequence.  At the paper's sensitive regime (4 QPs,
where correlated QP numbers alias into identical ports) the
queue-pair-aware scheme nearly eliminates the inflation — the gated
head-to-head.  At high QP counts the picture inverts by design: Algorithm
1 deliberately packs k QPs per uplink bin, so with 16 QPs over 4 bins the
64-bucket slot model charges its concentrated ports more than the
baseline's accidental spread — reported honestly, not gated.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.congestion import (
    build_link_load_matrix,
    congestion_report,
    ecmp_flow_weights,
)
from repro.core.fabric import Fabric
from repro.core.flows import Flow, route_flows_batched, route_flows_with_paths
from repro.core.metrics import load_factor
from repro.core.ports import allocate_ports, make_correlated_queue_pairs
from repro.core.wan import Netem

from .common import BenchRow, timed

QP_COUNTS = (4, 8, 16, 32)
TRIALS = 150
WEIGHTED_TRIALS = 40
BYTES_PER_QP = 1_000_000


def _all_equal_cost_links(fabric: Fabric, node: str, toward: str) -> Dict:
    """Byte counters over ALL equal-cost egress links (zeros included:
    with n_flows >= n_links an idle link IS imbalance — the paper's
    active-link threshold only guards the under-offered case)."""
    counted = fabric.uplink_bytes(node, toward=toward)
    if toward == "spine":
        peers = [s for s in fabric.spines if fabric.is_wan_link(node, s) is False
                 and s.startswith(node[:2])]
        for p in peers:
            counted.setdefault((node, p), 0)
    else:
        for link in fabric.wan_links:
            u, v = sorted(link)
            if node in (u, v):
                counted.setdefault((node, v if node == u else u), 0)
    return counted


def _one_trial(fabric: Fabric, num_qps: int, scheme: str, rng) -> Dict[str, float]:
    base = int(rng.integers(0, 2**31))
    qps = make_correlated_queue_pairs(num_qps, base_number=base)
    ports = allocate_ports(qps, scheme=scheme, k=4)
    flows = [
        Flow(src="d1h1", dst="d2h2", nbytes=BYTES_PER_QP, qp=qp, src_port=port)
        for qp, port in zip(qps, ports)
    ]
    route_flows_batched(fabric, flows)
    leaf = load_factor(_all_equal_cost_links(fabric, "d1l1", "spine"), threshold=-1)
    spine_bytes: Dict = {}
    for s in ("d1s1", "d1s2"):
        spine_bytes.update(_all_equal_cost_links(fabric, s, "wan"))
    spine = load_factor(spine_bytes, threshold=-1)
    return {"leaf": leaf.load_factor, "spine": spine.load_factor}


def measure(num_qps: int) -> Dict[str, float]:
    fabric = Fabric()
    rng = np.random.default_rng(42)
    acc = {("baseline", "leaf"): [], ("baseline", "spine"): [],
           ("qp_aware", "leaf"): [], ("qp_aware", "spine"): []}
    for _ in range(TRIALS):
        base_seed = rng.integers(0, 2**31)
        for scheme in ("baseline", "qp_aware"):
            r = _one_trial(fabric, num_qps, scheme, np.random.default_rng(base_seed))
            acc[(scheme, "leaf")].append(r["leaf"])
            acc[(scheme, "spine")].append(r["spine"])
    out = {}
    for loc in ("leaf", "spine"):
        b = float(np.mean(acc[("baseline", loc)]))
        p = float(np.mean(acc[("qp_aware", loc)]))
        out[f"{loc}_baseline"] = b
        out[f"{loc}_qp_aware"] = p
        out[f"{loc}_improvement_pct"] = 100.0 * (b - p) / b if b > 0 else 0.0
    return out


def measure_weighted(num_qps: int) -> Dict[str, float]:
    """Weighted-congestion cost of the hash collisions each scheme leaves.

    Per trial: draw one correlated QP set (the §3.3 pathology) and give
    *both* port schemes the same draw — the head-to-head is scheme effect,
    not sampling noise.  Each flow batch is routed once with path+slot
    recording; the unweighted and ECMP-weighted max-min allocations are
    then solved over the same recorded matrix, and the reported slowdown
    is the mean completion-time inflation (weighted / unweighted) plus
    the mean worst collision depth.  Collision-free trials sit at exactly
    1.0; collisions pay in modeled seconds.  See the module docstring for
    why the schemes' ordering is regime-dependent (qp_aware wins the
    gated 4-QP pathology, concedes the 16-QP bin-packing regime).
    """
    fabric = Fabric()
    netem = Netem(fabric)
    rng = np.random.default_rng(1042)
    acc: Dict[str, List[float]] = {}
    for _ in range(WEIGHTED_TRIALS):
        base = int(rng.integers(0, 2**31))
        qps = make_correlated_queue_pairs(num_qps, base_number=base)
        for scheme in ("baseline", "qp_aware"):
            ports = allocate_ports(qps, scheme=scheme, k=4)
            flows = [
                Flow(src="d1h1", dst="d2h2", nbytes=BYTES_PER_QP, qp=qp, src_port=port)
                for qp, port in zip(qps, ports)
            ]
            _, paths = route_flows_with_paths(fabric, flows)
            matrix = build_link_load_matrix(fabric, netem, paths)
            nb = [f.nbytes for f in flows]
            unweighted = congestion_report(matrix, nb)
            weighted = congestion_report(matrix, nb, ecmp_flow_weights(matrix))
            acc.setdefault(f"{scheme}_slowdown", []).append(
                weighted.seconds / unweighted.seconds
            )
            acc.setdefault(f"{scheme}_worst_occ", []).append(
                float(weighted.max_slot_occ.max())
            )
    return {k: float(np.mean(v)) for k, v in acc.items()}


def run() -> List[BenchRow]:
    rows: List[BenchRow] = []
    leaf_imps, spine_imps = [], []
    for n in QP_COUNTS:
        res, us = timed(lambda n=n: measure(n))
        leaf_imps.append(res["leaf_improvement_pct"])
        spine_imps.append(res["spine_improvement_pct"])
        rows.append(
            BenchRow(
                name=f"fig11_12_load_factor_qps{n}",
                us_per_call=us / (2 * TRIALS),
                derived=(
                    f"leaf {res['leaf_baseline']:.3f}->{res['leaf_qp_aware']:.3f} "
                    f"({res['leaf_improvement_pct']:+.1f}%) | "
                    f"spine {res['spine_baseline']:.3f}->{res['spine_qp_aware']:.3f} "
                    f"({res['spine_improvement_pct']:+.1f}%)"
                ),
                metrics={
                    "leaf_qp_aware_factor": res["leaf_qp_aware"],
                    "spine_qp_aware_factor": res["spine_qp_aware"],
                },
            )
        )
    rows.append(
        BenchRow(
            name="fig11_12_peak_improvement",
            us_per_call=0.0,
            derived=(
                f"leaf peak {max(leaf_imps):.1f}% (paper 13.7%) | "
                f"spine peak {max(spine_imps):.1f}% (paper 9.9%)"
            ),
            metrics={
                "leaf_peak_improvement_pct": max(leaf_imps),
                "spine_peak_improvement_pct": max(spine_imps),
            },
        )
    )
    for n in (4, 16):
        res, us = timed(lambda n=n: measure_weighted(n))
        slow_base = res["baseline_slowdown"]
        slow_qp = res["qp_aware_slowdown"]
        if slow_base < 1.0 - 1e-9 or slow_qp < 1.0 - 1e-9:
            raise AssertionError(
                "weighted allocation can only slow the slowest flow down: "
                f"baseline {slow_base:.4f}, qp_aware {slow_qp:.4f}"
            )
        if n == 4 and slow_qp >= slow_base:
            # the paper's pathology regime: correlated 4-QP draws alias
            # into identical ports under the baseline scheme, and Algorithm
            # 1 must pay visibly less for it
            raise AssertionError(
                f"qp_aware must beat baseline at 4 QPs: x{slow_qp:.3f} vs "
                f"x{slow_base:.3f}"
            )
        rows.append(
            BenchRow(
                name=f"weighted_congestion_qps{n}",
                us_per_call=us / (2 * WEIGHTED_TRIALS),
                derived=(
                    f"hash-collision completion inflation: baseline "
                    f"x{slow_base:.3f} (worst slot occ "
                    f"{res['baseline_worst_occ']:.1f}) vs qp_aware "
                    f"x{slow_qp:.3f} (worst {res['qp_aware_worst_occ']:.1f})"
                ),
                metrics={"baseline_slowdown_factor": slow_base,
                         "qp_aware_slowdown_factor": slow_qp},
            )
        )
    return rows
