"""CollectiveSchedule gates: overlapped sync costing + simulator overhead.

Thin wrapper over ``repro.scenario`` (ISSUE 5): the serial-vs-pipelined
ring study is the library's ``rs_then_ag`` / ``rs_ag_overlap`` scenario
pair, and the compute-overlap row is the ``compute_overlap`` scenario —
this module keeps the standalone-phase floor computation and the
event-loop overhead harness (a pure wall-clock measurement, not a study).

Two hard gates for the phased schedule API (ISSUE 3 acceptance):

* **Overlap wins, physically.**  On the 2-DC fabric, where reduce-scatter
  and all-gather ring traffic share the WAN bottleneck links, the
  pipelined ``rs_ag_overlap`` schedule must cost *strictly less* than the
  serial ``rs_then_ag`` schedule (imbalanced per-link byte loads no longer
  stack and only one terminal propagation delay is paid) and *strictly
  more* than ``max(RS, AG)`` standalone (the phases really do contend).

* **The event loop stays cheap.**  On the 4-DC scaled topology
  (``bench_collectives.SCALED``: 128 hosts, 96 WAN links), the
  event-driven time-varying simulation of the two-phase overlap schedule
  must finish within 10x of the single-shot max-min analysis of the same
  flow set (routing + matrix build + one water-filling solve) — the extra
  allocation epochs must not change the costing's complexity class.

Plus comparison rows for the hierarchical MoE all-to-all (intra-DC
dispatch + leader-only WAN combine) against the flat all-to-all.
"""

from __future__ import annotations

from typing import List

from repro.core.congestion import route_and_analyze, simulate_schedule
from repro.core.fabric import Fabric
from repro.core.flows import all_gather_flows, reduce_scatter_flows
from repro.core.schedule import CollectiveSchedule, Phase
from repro.core.wan import Netem
from repro.scenario import TopologySpec, get_scenario, run_scenario
from repro.scenario.library import AR_GRAD_BYTES, CALIBRATED_COMPUTE_S

from .bench_collectives import SCALED
from .common import BenchRow, timed

MOE_BYTES = 64_000_000
MAX_SIM_OVERHEAD = 10.0


def _overlap_gate(rows: List[BenchRow]) -> None:
    serial_res = run_scenario(get_scenario("rs_then_ag"))
    overlap_res = run_scenario(get_scenario("rs_ag_overlap"))
    serial, overlap = serial_res.sync, overlap_res.sync
    # the standalone halves, as single-phase schedules on the same fabric
    geo = overlap_res.geo
    ctx = geo.strategy_context()
    workers = list(ctx.workers)
    fkw = ctx.flow_kw
    opts = overlap_res.scenario.options
    rs = geo.sync_cost(
        CollectiveSchedule.single(
            "rs", reduce_scatter_flows(workers, AR_GRAD_BYTES, **fkw)
        ),
        options=opts,
    )
    ag = geo.sync_cost(
        CollectiveSchedule.single(
            "ag", all_gather_flows(workers, AR_GRAD_BYTES, **fkw)
        ),
        options=opts,
    )
    floor = max(rs.wan_seconds, ag.wan_seconds)
    assert overlap.wan_seconds < serial.wan_seconds, (
        f"rs_ag_overlap ({overlap.wan_seconds:.4f}s) must beat serial "
        f"rs_then_ag ({serial.wan_seconds:.4f}s) on shared bottlenecks"
    )
    assert overlap.wan_seconds > floor, (
        f"rs_ag_overlap ({overlap.wan_seconds:.4f}s) cannot beat the "
        f"contention-free floor max(RS, AG) ({floor:.4f}s)"
    )
    rows.append(
        BenchRow(
            name="schedule_rs_ag_overlap_vs_serial",
            us_per_call=float(overlap.wan_seconds * 1e6),
            derived=(
                f"overlap={overlap.wan_seconds:.3f}s serial={serial.wan_seconds:.3f}s "
                f"rs={rs.wan_seconds:.3f}s ag={ag.wan_seconds:.3f}s "
                f"saved={(serial.wan_seconds - overlap.wan_seconds) * 1e3:.1f}ms "
                f"(max<overlap<serial gate)"
            ),
            metrics={
                "overlap_seconds": overlap.wan_seconds,
                "serial_seconds": serial.wan_seconds,
            },
        )
    )
    rows.append(
        BenchRow(
            name="schedule_rs_ag_overlap_phases",
            us_per_call=0.0,
            derived=" ".join(
                f"{p.name}:[{p.start_s:.3f}s,{p.end_s:.3f}s]" for p in overlap.phases
            ),
        )
    )


def _simulator_overhead_gate(rows: List[BenchRow]) -> None:
    fabric = Fabric(SCALED)
    netem = Netem(fabric)
    workers = sorted(fabric.hosts)[::4]  # 32 of 128 hosts, spread over DCs
    rs = reduce_scatter_flows(workers, AR_GRAD_BYTES, num_channels=4)
    ag = all_gather_flows(workers, AR_GRAD_BYTES, num_channels=4)
    schedule = CollectiveSchedule("rs_ag_overlap", (Phase("rs", rs), Phase("ag", ag)))
    # warm the routing tables so both sides time steady-state costing
    route_and_analyze(fabric, netem, rs + ag)
    _, t_single = timed(lambda: route_and_analyze(fabric, netem, rs + ag))
    report, t_sim = timed(lambda: simulate_schedule(fabric, netem, schedule))
    ratio = t_sim / t_single
    assert ratio <= MAX_SIM_OVERHEAD, (
        f"event-driven simulation {t_sim / 1e3:.1f}ms vs single-shot "
        f"{t_single / 1e3:.1f}ms = {ratio:.1f}x > {MAX_SIM_OVERHEAD}x budget"
    )
    rows.append(
        BenchRow(
            name="schedule_sim_overhead_4dc",
            us_per_call=t_sim,
            derived=(
                f"{len(workers)} workers {len(rs) + len(ag)} flows: "
                f"event-driven={t_sim / 1e3:.1f}ms single-shot={t_single / 1e3:.1f}ms "
                f"ratio={ratio:.2f}x (gate <={MAX_SIM_OVERHEAD:.0f}x); "
                f"makespan={report.seconds:.2f}s "
                f"eff_wan={report.effective_wan_gbps:.2f}Gbit/s"
            ),
        )
    )


def _moe_rows(rows: List[BenchRow]) -> None:
    # the MoE pair needs 4 workers per pod: widen the library topology,
    # keep its costing options
    opts = get_scenario("rs_then_ag").options
    moe_geo = TopologySpec(num_pods=2, workers_per_pod=4, num_channels=4, seed=3).build()
    flat = moe_geo.sync_cost("alltoall", MOE_BYTES, options=opts)
    hier = moe_geo.sync_cost("hier_alltoall", MOE_BYTES, options=opts)
    wan_flows = "leader-only WAN flows vs per-host WAN flows"
    rows.append(
        BenchRow(
            name="schedule_hier_alltoall_vs_flat",
            us_per_call=float(hier.wan_seconds * 1e6),
            derived=(
                f"hier={hier.wan_seconds:.3f}s "
                f"(dispatch={hier.phases[0].duration_s:.3f}s "
                f"combine={hier.phases[1].duration_s:.3f}s) "
                f"flat={flat.wan_seconds:.3f}s; same WAN bytes "
                f"({hier.wan_bytes / 1e6:.0f}MB vs {flat.wan_bytes / 1e6:.0f}MB), "
                f"{wan_flows}"
            ),
            metrics={
                "hier_alltoall_seconds": hier.wan_seconds,
                "flat_alltoall_seconds": flat.wan_seconds,
            },
        )
    )


def _compute_overlap_row(rows: List[BenchRow]) -> None:
    spec0 = get_scenario("compute_overlap", overlap_fraction=0.0)
    spec1 = get_scenario("compute_overlap", overlap_fraction=1.0)
    serial = run_scenario(spec0).steps[0].seconds
    res1 = run_scenario(spec1)
    full = res1.steps[0].seconds
    comm = res1.sync.wan_seconds
    rows.append(
        BenchRow(
            name="schedule_compute_overlap_step",
            us_per_call=float(full * 1e6),
            derived=(
                f"comm={comm:.3f}s compute={CALIBRATED_COMPUTE_S}s: step f=0 "
                f"{serial:.3f}s, f=1 {full:.3f}s = max(compute, comm) — comm "
                f"is never overlapped below its bandwidth floor"
            ),
        )
    )


def run() -> List[BenchRow]:
    rows: List[BenchRow] = []
    _overlap_gate(rows)
    _simulator_overhead_gate(rows)
    _moe_rows(rows)
    _compute_overlap_row(rows)
    return rows
