"""Figs. 9 & 13 reproduction: link-failure recovery, BFD vs BGP timers.

Paper: BFD (10 ms x 3) recovers in ~110 ms; default BGP hold timers take
~180 s.  Also verifies traffic actually reroutes around the failed WAN
link, and reports the training-layer recovery economics (the TPU-side
adaptation, runtime/failure.py).

Thin wrapper over the scenario library (ISSUE 5): the scaled topology
(``SCALED8``), the deterministic flap scripts (``storm_events`` /
``evpn_storm_events``) and the storm gradient volume live in
``repro.scenario.library``; this module keeps only the measurement harness
(incremental vs full-invalidation timing, byte-identity checks) and a
scenario-driven recovery row.

Beyond the paper's 2-DC scale (ISSUE 2 tentpole): an 8-DC BFD flap storm
with >=10k live flows compares the fabric's incremental re-convergence
(link->destination dependency index + in-place next-hop-table patches)
against full cache invalidation, gated on >=10x speedup with
byte-identical ``route_flows_batched`` counters — plus the flow-level
congestion model's reproduction of the ~800 Mbit/s effective spine-WAN
throughput (§5.5).

ISSUE 4 tentpole: the same storm (plus a leaf-isolation episode, the one
event class that actually partitions the BGP session graph) drives the
*control plane* — ``EvpnControlPlane.resync_incremental`` piggybacking on
each flap's ``RerouteStats`` must touch <20% of VTEPs on average while
ending byte-identical (RIBs + MAC/IP/flood tables) to a control plane
that full-``resync()``s after every event.
"""

from __future__ import annotations

import time
from typing import List, Tuple

from repro.core.bfd import FailureDetector
from repro.core.evpn import EvpnControlPlane
from repro.core.fabric import Fabric
from repro.core.flows import all_to_all_flows, ring_allreduce_flows, route_flows_batched
from repro.core.wan import Netem, WanTimingModel
from repro.runtime.failure import plan_recovery
from repro.scenario import get_scenario, run_scenario
from repro.scenario.library import (
    SCALED8,
    STORM_GRAD_BYTES,
    evpn_storm_events as _evpn_storm_events,
    storm_events as _storm_events,
)

from .common import BenchRow, timed

MIN_STORM_SPEEDUP = 10.0
MAX_EVPN_TOUCHED_FRAC = 0.20


def _learned_control_plane(fabric: Fabric) -> EvpnControlPlane:
    evpn = EvpnControlPlane(fabric)
    for host in sorted(fabric.hosts):
        evpn.learn_host(host, 100)
    return evpn


def _evpn_state(evpn: EvpnControlPlane):
    """The full control-plane session state, for byte-identity checks."""
    return (
        {name: frozenset(sp.rib) for name, sp in evpn.speakers.items()},
        evpn.mac_table,
        evpn.ip_table,
        evpn.flood_list,
    )


def _run_evpn_storm(
    fabric: Fabric,
    evpn: EvpnControlPlane,
    events: List[Tuple[str, Tuple[str, str]]],
    *,
    full_resync: bool,
) -> Tuple[float, List[float], int]:
    """Apply the storm, resyncing the control plane after every flap.

    Returns (EVPN resync seconds, per-event VTEP-touched fractions, total
    speakers touched) — the data-plane reroute itself is excluded from the
    timing so the comparison isolates control-plane cost.
    """
    touched_fracs: List[float] = []
    touched_total = 0
    elapsed = 0.0
    for action, (u, v) in events:
        stats = (
            fabric.fail_link(u, v) if action == "fail" else fabric.restore_link(u, v)
        )
        t0 = time.perf_counter()
        if full_resync:
            evpn.resync()
        else:
            es = evpn.resync_incremental(stats)
            touched_fracs.append(es.vtep_touched_frac)
            touched_total += es.touched
        elapsed += time.perf_counter() - t0
    return elapsed, touched_fracs, touched_total


def _run_storm(
    fabric: Fabric,
    events: List[Tuple[str, Tuple[str, str]]],
    leaves: List[str],
    *,
    full_invalidation: bool,
) -> Tuple[float, int, int, int]:
    """Apply the storm; after every BFD event, re-converge the routing
    tables for every egress leaf the live flows use.  Returns (seconds,
    tables patched in place, tables rebuilt, distinct destinations in the
    emitted blast radius — ``RerouteStats.affected_dsts``)."""
    det = FailureDetector(fabric)
    patched = rebuilt = 0
    blast: set = set()
    t0 = time.perf_counter()
    for action, (u, v) in events:
        if action == "fail":
            stats = det.fail_and_recover((u, v), mechanism="bfd").reroute
        else:
            stats = det.restore((u, v))
        if full_invalidation:
            fabric.flush_routing_state()
        else:
            patched += stats.patched
            rebuilt += stats.rebuilt
            blast.update(stats.affected_dsts)
        fabric.compile_routes(leaves)
    return time.perf_counter() - t0, patched, rebuilt, len(blast)


def run() -> List[BenchRow]:
    rows: List[BenchRow] = []
    fabric = Fabric()
    evpn = EvpnControlPlane(fabric)
    det = FailureDetector(fabric, evpn)
    wan = sorted(fabric.wan_links[0])

    tl_bfd, us1 = timed(lambda: det.fail_and_recover((wan[0], wan[1]), mechanism="bfd"))
    det.restore((wan[0], wan[1]))
    rows.append(
        BenchRow(
            name="fig9_bfd_recovery",
            us_per_call=us1,
            derived=f"recovery={tl_bfd.recovery_ms:.0f}ms (paper ~110ms); "
            f"detect={tl_bfd.detected_at_ms - tl_bfd.failure_at_ms:.0f}ms",
            metrics={"recovery_ms": tl_bfd.recovery_ms},
        )
    )

    tl_bgp, us2 = timed(lambda: det.fail_and_recover((wan[0], wan[1]), mechanism="bgp"))
    det.restore((wan[0], wan[1]))
    rows.append(
        BenchRow(
            name="fig13_bgp_recovery",
            us_per_call=us2,
            derived=f"recovery={tl_bgp.recovery_ms / 1e3:.1f}s (paper ~180s)",
            metrics={"recovery_seconds": tl_bgp.recovery_ms / 1e3},
        )
    )

    # reroute correctness: all flows avoid the failed link, none dropped
    det.fail_and_recover((wan[0], wan[1]), mechanism="bfd")
    fabric.reset_counters()
    rerouted = 0
    for port in range(49192, 49192 + 128):
        path = fabric.send("d1h1", "d2h1", 1000, src_port=port)
        assert (wan[0], wan[1]) not in list(zip(path, path[1:]))
        rerouted += 1
    det.restore((wan[0], wan[1]))
    rows.append(
        BenchRow(
            name="fig9_reroute_correctness",
            us_per_call=0.0,
            derived=f"{rerouted}/128 flows rerouted, 0 blackholed",
        )
    )

    # the training-layer analogue: detection latency dominates lost work
    plan = plan_recovery(
        step=1000, last_checkpoint_step=990, step_time_s=8.0,
        detect_time_ms=30.0, checkpoint_bytes=328e6 * 3,
    )
    plan_slow = plan_recovery(
        step=1000, last_checkpoint_step=990, step_time_s=8.0,
        detect_time_ms=180_000.0, checkpoint_bytes=328e6 * 3,
    )
    rows.append(
        BenchRow(
            name="training_recovery_economics",
            us_per_call=0.0,
            derived=(
                f"BFD-style heartbeats: {plan.total_cost_s:.0f}s total cost vs "
                f"BGP-style timeouts: {plan_slow.total_cost_s:.0f}s "
                f"(lost work {plan.lost_work_s:.0f}s both)"
            ),
        )
    )

    # -- 8-DC BFD flap storm: incremental vs full-invalidation (tentpole) --
    fab_inc = Fabric(SCALED8)
    fab_full = Fabric(SCALED8)
    storm_flows = all_to_all_flows(list(fab_inc.hosts), STORM_GRAD_BYTES)
    assert len(storm_flows) >= 10_000, len(storm_flows)
    leaves = sorted({fab_inc.hosts[f.dst].leaf for f in storm_flows})
    events = _storm_events(fab_inc)
    # warm both engines (pair registry, CRC columns, next-hop tables)
    route_flows_batched(fab_inc, storm_flows)
    route_flows_batched(fab_full, storm_flows)

    inc_s, patched, rebuilt, blast_dsts = _run_storm(
        fab_inc, events, leaves, full_invalidation=False
    )
    full_s, _, _, _ = _run_storm(fab_full, events, leaves, full_invalidation=True)
    speedup = full_s / inc_s

    # byte-identical routing across the storm: both survivors must match a
    # freshly built fabric carrying the same down-link set
    down: set = set()
    for action, link in events:
        (down.add if action == "fail" else down.discard)(link)
    fresh = Fabric(SCALED8)
    for link in sorted(down):
        fresh.fail_link(*link)
    inc_counters = route_flows_batched(fab_inc, storm_flows)
    full_counters = route_flows_batched(fab_full, storm_flows)
    ref_counters = route_flows_batched(fresh, storm_flows)
    if not (inc_counters == ref_counters == full_counters):
        raise AssertionError("incremental re-convergence diverged from fresh build")

    rows.append(
        BenchRow(
            name="flap_storm_incremental",
            us_per_call=inc_s * 1e6 / len(events),
            derived=(
                f"{len(events)} BFD flaps, {len(storm_flows)} live flows | "
                f"{patched} tables patched in place, {rebuilt} rebuilt, "
                f"blast radius {blast_dsts}/{len(leaves)} egress leaves "
                f"(RerouteStats.affected_dsts)"
            ),
        )
    )
    rows.append(
        BenchRow(
            name="flap_storm_full_invalidation",
            us_per_call=full_s * 1e6 / len(events),
            derived=f"{len(leaves)} egress-leaf tables rebuilt per flap",
        )
    )
    rows.append(
        BenchRow(
            name="flap_storm_speedup",
            us_per_call=0.0,
            derived=(
                f"incremental {inc_s * 1e3:.1f}ms vs full {full_s * 1e3:.1f}ms = "
                f"{speedup:.1f}x (target >={MIN_STORM_SPEEDUP:.0f}x); "
                f"byte-identical with {len(down)} links left down"
            ),
        )
    )
    if speedup < MIN_STORM_SPEEDUP:
        raise AssertionError(
            f"incremental re-convergence speedup {speedup:.1f}x below "
            f"{MIN_STORM_SPEEDUP:.0f}x target"
        )

    # -- incremental EVPN resync storm (ISSUE 4 control-plane tentpole) ------
    fab_einc = Fabric(SCALED8)
    fab_efull = Fabric(SCALED8)
    evpn_inc = _learned_control_plane(fab_einc)
    evpn_full = _learned_control_plane(fab_efull)
    evpn_events = _evpn_storm_events(fab_einc)
    inc_evpn_s, fracs, touched_total = _run_evpn_storm(
        fab_einc, evpn_inc, evpn_events, full_resync=False
    )
    full_evpn_s, _, _ = _run_evpn_storm(
        fab_efull, evpn_full, evpn_events, full_resync=True
    )
    if _evpn_state(evpn_inc) != _evpn_state(evpn_full):
        raise AssertionError(
            "incremental EVPN resync diverged from full resync session state"
        )
    mean_frac = sum(fracs) / len(fracs)
    evpn_speedup = full_evpn_s / inc_evpn_s if inc_evpn_s > 0 else float("inf")
    rows.append(
        BenchRow(
            name="evpn_resync_incremental_storm",
            us_per_call=inc_evpn_s * 1e6 / len(evpn_events),
            derived=(
                f"{len(evpn_events)} flaps (incl. d5l1 isolation), "
                f"{len(fab_einc.leaves)} VTEPs | mean touched "
                f"{100 * mean_frac:.1f}% of VTEPs (gate <"
                f"{100 * MAX_EVPN_TOUCHED_FRAC:.0f}%), max "
                f"{100 * max(fracs):.0f}%, {touched_total} speaker RIB edits | "
                f"incremental {inc_evpn_s * 1e3:.1f}ms vs full resync "
                f"{full_evpn_s * 1e3:.1f}ms = {evpn_speedup:.1f}x; "
                f"session state byte-identical"
            ),
            metrics={"evpn_mean_touched_frac": mean_frac},
        )
    )
    if mean_frac >= MAX_EVPN_TOUCHED_FRAC:
        raise AssertionError(
            f"EVPN incremental resync touched {100 * mean_frac:.1f}% of VTEPs "
            f"on average, gate is <{100 * MAX_EVPN_TOUCHED_FRAC:.0f}%"
        )

    # -- the storm as a declarative scenario (ISSUE 5) -----------------------
    storm = run_scenario(get_scenario("bfd_flap_storm"))
    assert len(storm.recoveries) == 12, len(storm.recoveries)
    mean_rec = sum(t.recovery_ms for t in storm.recoveries) / len(storm.recoveries)
    rows.append(
        BenchRow(
            name="scenario_bfd_flap_storm",
            us_per_call=0.0,
            derived=(
                f"{len(storm.steps)} storm steps via run_scenario: "
                f"{len(storm.recoveries)} recoveries (mean {mean_rec:.0f}ms), "
                f"{len(storm.evpn_resyncs)} EVPN resyncs (mean touched "
                f"{100 * storm.evpn_mean_touched_frac:.1f}%), leader sync "
                f"{storm.mean_step_seconds:.3f}s/step through the storm"
            ),
            metrics={
                "storm_mean_recovery_ms": mean_rec,
                "storm_mean_step_seconds": storm.mean_step_seconds,
            },
        )
    )

    # -- flow-level congestion model: effective spine-WAN throughput (§5.5) --
    cfab = Fabric()
    model = WanTimingModel(Netem(cfab))
    ring = ring_allreduce_flows(list(cfab.hosts), 64_000_003)
    report, us_c = timed(lambda: model.contended_transfer_time(ring))
    eff = report.effective_wan_gbps
    rows.append(
        BenchRow(
            name="congestion_spine_throughput",
            us_per_call=us_c,
            derived=(
                f"{len(ring)} contended flows | effective WAN "
                f"{eff * 1e3:.0f} Mbit/s (paper ~800), completion "
                f"{report.seconds:.2f}s vs ideal "
                f"{model.transfer_time(dict(cfab.link_bytes)).seconds:.2f}s"
            ),
            metrics={
                "effective_wan_mbps": eff * 1e3,
                "completion_seconds": report.seconds,
            },
        )
    )
    if not 0.72 <= eff <= 0.8 * (1 + 1e-6):
        raise AssertionError(
            f"effective WAN throughput {eff:.3f} Gbit/s outside the "
            "800 Mbit/s-class band (paper §5.5)"
        )
    return rows
