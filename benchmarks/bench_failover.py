"""Figs. 9 & 13 reproduction: link-failure recovery, BFD vs BGP timers.

Paper: BFD (10 ms x 3) recovers in ~110 ms; default BGP hold timers take
~180 s.  Also verifies traffic actually reroutes around the failed WAN
link, and reports the training-layer recovery economics (the TPU-side
adaptation, runtime/failure.py).
"""

from __future__ import annotations

from typing import List

from repro.core.bfd import FailureDetector
from repro.core.evpn import EvpnControlPlane
from repro.core.fabric import Fabric
from repro.runtime.failure import plan_recovery

from .common import BenchRow, timed


def run() -> List[BenchRow]:
    rows: List[BenchRow] = []
    fabric = Fabric()
    evpn = EvpnControlPlane(fabric)
    det = FailureDetector(fabric, evpn)
    wan = sorted(fabric.wan_links[0])

    tl_bfd, us1 = timed(lambda: det.fail_and_recover((wan[0], wan[1]), mechanism="bfd"))
    det.restore((wan[0], wan[1]))
    rows.append(
        BenchRow(
            name="fig9_bfd_recovery",
            us_per_call=us1,
            derived=f"recovery={tl_bfd.recovery_ms:.0f}ms (paper ~110ms); "
            f"detect={tl_bfd.detected_at_ms - tl_bfd.failure_at_ms:.0f}ms",
        )
    )

    tl_bgp, us2 = timed(lambda: det.fail_and_recover((wan[0], wan[1]), mechanism="bgp"))
    det.restore((wan[0], wan[1]))
    rows.append(
        BenchRow(
            name="fig13_bgp_recovery",
            us_per_call=us2,
            derived=f"recovery={tl_bgp.recovery_ms / 1e3:.1f}s (paper ~180s)",
        )
    )

    # reroute correctness: all flows avoid the failed link, none dropped
    det.fail_and_recover((wan[0], wan[1]), mechanism="bfd")
    fabric.reset_counters()
    rerouted = 0
    for port in range(49192, 49192 + 128):
        path = fabric.send("d1h1", "d2h1", 1000, src_port=port)
        assert (wan[0], wan[1]) not in list(zip(path, path[1:]))
        rerouted += 1
    det.restore((wan[0], wan[1]))
    rows.append(
        BenchRow(
            name="fig9_reroute_correctness",
            us_per_call=0.0,
            derived=f"{rerouted}/128 flows rerouted, 0 blackholed",
        )
    )

    # the training-layer analogue: detection latency dominates lost work
    plan = plan_recovery(
        step=1000, last_checkpoint_step=990, step_time_s=8.0,
        detect_time_ms=30.0, checkpoint_bytes=328e6 * 3,
    )
    plan_slow = plan_recovery(
        step=1000, last_checkpoint_step=990, step_time_s=8.0,
        detect_time_ms=180_000.0, checkpoint_bytes=328e6 * 3,
    )
    rows.append(
        BenchRow(
            name="training_recovery_economics",
            us_per_call=0.0,
            derived=(
                f"BFD-style heartbeats: {plan.total_cost_s:.0f}s total cost vs "
                f"BGP-style timeouts: {plan_slow.total_cost_s:.0f}s "
                f"(lost work {plan.lost_work_s:.0f}s both)"
            ),
        )
    )
    return rows
