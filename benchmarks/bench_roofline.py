"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads artifacts/dryrun/<arch>__<shape>__single.json and prints per-cell:
compute/memory/collective seconds (v5e-class constants), the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs ratio, and the per-device memory fit.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import List

from .common import BenchRow

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"
HBM_PER_CHIP = 16 * 2**30  # v5e-class


def run() -> List[BenchRow]:
    rows: List[BenchRow] = []
    cells = sorted(ARTIFACTS.glob("*__single.json"))
    if not cells:
        return [
            BenchRow(
                name="roofline_missing",
                us_per_call=0.0,
                derived="run `python -m repro.launch.dryrun --all` first",
            )
        ]
    n_ok = n_skip = n_err = 0
    for path in cells:
        rec = json.loads(path.read_text())
        name = f"roofline_{rec['arch']}_{rec['shape']}"
        if rec["status"] == "skipped":
            n_skip += 1
            rows.append(BenchRow(name=name, us_per_call=0.0, derived=f"N/A: {rec['reason']}"))
            continue
        if rec["status"] != "ok" or "roofline" not in rec:
            n_err += 1
            rows.append(
                BenchRow(name=name, us_per_call=0.0, derived=f"ERROR: {rec.get('error', '?')[:80]}")
            )
            continue
        n_ok += 1
        r = rec["roofline"]
        mem = rec["main"]["memory"]["peak_estimate_bytes"]
        fits = "fits" if mem <= HBM_PER_CHIP else f"OVER ({mem / 2**30:.1f}GiB)"
        rows.append(
            BenchRow(
                name=name,
                us_per_call=rec.get("compile_seconds", 0.0) * 1e6,
                derived=(
                    f"compute={r['compute_s'] * 1e3:.1f}ms mem={r['memory_s'] * 1e3:.1f}ms "
                    f"coll={r['collective_s'] * 1e3:.1f}ms bottleneck={r['bottleneck']} "
                    f"flops_ratio={r['model_flops_ratio']:.2f} hbm={fits}"
                ),
            )
        )
    rows.append(
        BenchRow(
            name="roofline_summary",
            us_per_call=0.0,
            derived=f"{n_ok} cells ok, {n_skip} skipped (long_500k full-attn), {n_err} errors",
        )
    )
    return rows
