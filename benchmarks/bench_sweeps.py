"""Sweep-engine suite: the fiber-latency campaign, executed and gated.

ISSUE 6 tentpole demo: the per-DC-pair asymmetric-WAN axis
(``TopologySpec.wan_pairs``) crossed with the compute/communication
overlap fraction reproduces the Papavasileiou-style
overlap-benefit-vs-RTT curve ("Modeling the Impact of Fiber Latency on
Compute-Communication Overlap", PAPERS.md) as one
:func:`repro.scenario.fiber_latency_campaign` spec.  Every variant of the
joined table lands as one gated ``BenchRow`` (``BENCH_sweeps.json``), so
campaign conclusions are regression-gated like everything else.

Cross-variant gates (the study conclusions, not just the numbers):

* overlap benefit — the fraction of the no-overlap step time overlap
  recovers — is monotonically non-increasing as per-pair RTT grows past
  the compute window (propagation is exposed no matter when
  communication starts), and strictly decays end to end;
* a >=2-worker process-pool run of the same campaign produces a joined
  table identical to the serial run (seeded determinism: worker count
  never changes results), and so does a re-run of ``random_campaign``
  from the same seed.
"""

from __future__ import annotations

from typing import List

from repro.scenario import fiber_latency_campaign, random_campaign, run_sweep
from repro.scenario.sweep import overlap_benefit_curve

from .common import BenchRow, timed

CAMPAIGN_SEED = 6


def run() -> List[BenchRow]:
    rows: List[BenchRow] = []

    sweep = fiber_latency_campaign()
    serial, us = timed(lambda: run_sweep(sweep))
    for r in serial.rows:
        rows.append(
            BenchRow(
                name=f"fiber_{r.name}",
                us_per_call=us / len(serial.rows),
                derived=f"step={r.metrics['mean_step_seconds']:.3f}s",
                metrics=dict(r.metrics),
            )
        )

    # -- gate: overlap benefit decays monotonically with per-pair RTT --------
    curve = overlap_benefit_curve(serial)
    for (rtt_a, ben_a), (rtt_b, ben_b) in zip(curve, curve[1:]):
        if ben_b > ben_a + 1e-9:
            raise AssertionError(
                f"overlap benefit must not grow with RTT: "
                f"{ben_a:.4f}@{rtt_a}ms -> {ben_b:.4f}@{rtt_b}ms"
            )
    if not curve[-1][1] < curve[0][1]:
        raise AssertionError(
            f"overlap benefit must strictly decay across the sweep "
            f"({curve[0][1]:.4f} -> {curve[-1][1]:.4f})"
        )

    # -- gate: >=2-worker run joins to the identical table -------------------
    parallel, par_us = timed(lambda: run_sweep(sweep, workers=2))
    if [r.to_dict() for r in parallel.rows] != [r.to_dict() for r in serial.rows]:
        raise AssertionError("2-worker sweep table differs from the serial run")

    # -- gate: random campaigns are a deterministic artifact of their seed ---
    mc = run_sweep(random_campaign(seed=CAMPAIGN_SEED, variants=4))
    mc_again = run_sweep(random_campaign(seed=CAMPAIGN_SEED, variants=4), workers=2)
    if [r.to_dict() for r in mc.rows] != [r.to_dict() for r in mc_again.rows]:
        raise AssertionError("random_campaign is not seed-deterministic")
    for r in mc.rows:
        rows.append(
            BenchRow(
                name=f"campaign_{r.name}",
                us_per_call=0.0,
                derived=f"{len(r.overrides)} overrides",
                metrics=dict(r.metrics),
            )
        )

    rows.append(
        BenchRow(
            name="sweep_gates",
            us_per_call=par_us,
            derived=(
                f"benefit {curve[0][1]:.3f}@{curve[0][0]:g}ms -> "
                f"{curve[-1][1]:.3f}@{curve[-1][0]:g}ms (monotone) | "
                f"2-worker table == serial | campaign seed-deterministic"
            ),
            metrics={
                "overlap_benefit_min_rtt": curve[0][1],
                "overlap_benefit_max_rtt": curve[-1][1],
            },
        )
    )
    return rows
