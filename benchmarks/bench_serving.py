"""Geo-serving suite: inference co-load on the training fabric, gated.

ISSUE 8 tentpole gates (study conclusions, not just numbers):

* **co-scheduling contention** — the same deterministic request trace is
  priced twice, on a quiescent fabric and co-scheduled with a flat
  AllReduce: training must *strictly* inflate serving p99 (shared
  links, one max-min allocator — the "99 Problems" thesis, networking
  binds both workloads);
* **goodput-under-flap** — ``serving_under_flap``: the SLO-miss window
  must coincide with the brownout/flap, the failover sweep must migrate
  a nonzero number of sessions (paying WAN KV bytes), and goodput must
  fully recover afterwards — the whole arc, trip -> migrate -> recover;
* **trace determinism** — a sweep over serving seeds joins to a
  byte-identical table serial vs 2-worker process pool (serving results
  are a pure function of the spec).

Every run's ``metrics()`` land as gated rows (``BENCH_serving.json``)
under ``benchmarks/compare.py`` — ``serving_p99_ms``/``_p50``-suffixed
metrics gate lower-is-better.
"""

from __future__ import annotations

from typing import List

from repro.scenario import (
    Scenario,
    ServingSpec,
    Sweep,
    SyncOptions,
    TopologySpec,
    WorkloadSpec,
    get_scenario,
    run_scenario,
    run_sweep,
)
from repro.scenario.library import AR_GRAD_BYTES, DISTILGPT2_KV_BYTES_PER_TOKEN

from .common import BenchRow, timed

#: the shared co-load both contention scenarios price
COLOAD = ServingSpec(
    users=300_000,
    requests_per_user_step=3e-5,
    remote_fraction=0.25,
    mean_tokens=128,
    session_tokens=1024,
    kv_bytes_per_token=DISTILGPT2_KV_BYTES_PER_TOKEN,
    slo_ms=400.0,
    seed=31,
)


def _contention_scenario(name: str, strategy) -> Scenario:
    return Scenario(
        name=name,
        topology=TopologySpec(num_pods=2, workers_per_pod=2, num_channels=4, seed=3),
        workload=WorkloadSpec(strategy=strategy, grad_bytes=AR_GRAD_BYTES, steps=8),
        options=SyncOptions(jitter=False),
        serving=COLOAD,
        description="serving co-load contention study",
    )


def run() -> List[BenchRow]:
    rows: List[BenchRow] = []

    # -- gate: co-scheduled training strictly inflates serving p99 -----------
    quiescent, us_q = timed(
        lambda: run_scenario(_contention_scenario("serving_quiescent", None))
    )
    cosched, us_c = timed(
        lambda: run_scenario(_contention_scenario("serving_cosched", "allreduce"))
    )
    p99_q = quiescent.metrics()["serving_p99_ms"]
    p99_c = cosched.metrics()["serving_p99_ms"]
    if not p99_c > p99_q:
        raise AssertionError(
            f"co-scheduled training must inflate serving p99: quiescent "
            f"{p99_q:.1f}ms vs co-scheduled {p99_c:.1f}ms"
        )
    if quiescent.metrics()["serving_requests"] != cosched.metrics()["serving_requests"]:
        raise AssertionError("both runs must price the identical request trace")
    rows.append(
        BenchRow(
            name="serving_quiescent",
            us_per_call=us_q,
            derived=(
                f"{int(quiescent.metrics()['serving_requests'])} requests, "
                f"p99 {p99_q:.1f}ms (no training)"
            ),
            metrics=quiescent.metrics(),
        )
    )
    rows.append(
        BenchRow(
            name="serving_cosched",
            us_per_call=us_c,
            derived=(
                f"same trace under AllReduce: p99 {p99_c:.1f}ms "
                f"({p99_c / p99_q:.1f}x quiescent)"
            ),
            metrics=cosched.metrics(),
        )
    )

    # -- gate: goodput-under-flap recovers after failover ---------------------
    flap, us_f = timed(lambda: run_scenario(get_scenario("serving_under_flap")))
    spec = flap.scenario
    degrade_at = next(
        e.at_step for e in spec.events if e.kind == "degrade_pair"
    )
    per_step = {s.step: s for s in flap.serving_steps}
    migrate_step = next(
        (s.step for s in flap.serving_steps if s.migrated_sessions > 0), None
    )
    if migrate_step is None:
        raise AssertionError("failover must migrate a nonzero session count")
    if not migrate_step > degrade_at:
        raise AssertionError(
            f"migration at step {migrate_step} must follow the brownout "
            f"at step {degrade_at} (detection has hysteresis)"
        )
    if flap.metrics()["serving_migration_bytes"] <= 0:
        raise AssertionError("migrated sessions must pay WAN KV bytes")
    flap_window = range(degrade_at, migrate_step)
    misses_in_flap = sum(per_step[s].slo_misses for s in flap_window)
    if misses_in_flap == 0:
        raise AssertionError("the brownout window must produce SLO misses")
    after = [s for s in flap.serving_steps if s.step >= migrate_step]
    misses_after = sum(s.slo_misses for s in after)
    if misses_after != 0:
        raise AssertionError(
            f"goodput must fully recover after failover; "
            f"{misses_after} misses from step {migrate_step} on"
        )
    p99_peak = max(per_step[s].p99_ms for s in flap_window)
    p99_after = max(s.p99_ms for s in after)
    if not p99_peak > 2.0 * p99_after:
        raise AssertionError(
            f"flap p99 peak {p99_peak:.0f}ms must clearly dominate "
            f"post-failover p99 {p99_after:.0f}ms"
        )
    rows.append(
        BenchRow(
            name="serving_under_flap",
            us_per_call=us_f,
            derived=(
                f"flap p99 peak {p99_peak:.0f}ms -> {p99_after:.0f}ms after "
                f"{int(flap.metrics()['serving_migrated_sessions'])} migrations "
                f"({flap.metrics()['serving_migration_bytes'] / 1e6:.0f} MB KV)"
            ),
            metrics=flap.metrics(),
        )
    )

    # -- gate: serving metrics byte-identical across sweep worker counts -----
    base = _contention_scenario("serving_seed_sweep", None)
    sweep = Sweep(
        base=base,
        overrides=tuple(
            {"name": f"seed{s:02d}", "serving.seed": s} for s in (5, 23, 31)
        ),
        name="serving_seed_sweep",
    )
    serial, us_sw = timed(lambda: run_sweep(sweep))
    parallel = run_sweep(sweep, workers=2)
    if [r.to_dict() for r in serial.rows] != [r.to_dict() for r in parallel.rows]:
        raise AssertionError(
            "serving sweep differs between serial and 2-worker runs"
        )
    for r in serial.rows:
        if "serving_p99_ms" not in r.metrics:
            raise AssertionError(f"variant {r.name} lost its serving metrics")
        rows.append(
            BenchRow(
                name=f"serving_sweep_{r.name}",
                us_per_call=us_sw / len(serial.rows),
                derived=f"p99 {r.metrics['serving_p99_ms']:.1f}ms",
                metrics=dict(r.metrics),
            )
        )
    return rows
