"""Shared benchmark plumbing: timing + the run.py CSV contract."""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional


@dataclasses.dataclass
class BenchRow:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def timed(fn: Callable[[], Any]) -> tuple[Any, float]:
    t0 = time.time()
    out = fn()
    return out, (time.time() - t0) * 1e6


HEADER = "name,us_per_call,derived"
