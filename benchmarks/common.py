"""Shared benchmark plumbing: timing + the run.py CSV contract.

``BenchRow.metrics`` carries the *gated* quantities a suite wants the CI
regression gate (``benchmarks/compare.py``) to track against the committed
``benchmarks/baselines/BENCH_*.json`` snapshots.  Only put
machine-independent, seeded model outputs there (seconds of modeled WAN
time, load factors, Mbit/s observables, VTEPs-touched fractions) — never
wall-clock timings like ``us_per_call``, which vary across runners and are
excluded from gating by design.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict


@dataclasses.dataclass
class BenchRow:
    name: str
    us_per_call: float
    derived: str
    #: deterministic metrics gated by benchmarks/compare.py (see module doc);
    #: direction (higher/lower is better) is inferred from the metric name —
    #: see ``benchmarks.compare.metric_direction``.
    metrics: Dict[str, float] = dataclasses.field(default_factory=dict)

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def timed(fn: Callable[[], Any]) -> tuple[Any, float]:
    t0 = time.time()
    out = fn()
    return out, (time.time() - t0) * 1e6


HEADER = "name,us_per_call,derived"
