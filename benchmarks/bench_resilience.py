"""Resilience suite: gray failures, SRLG cuts, pod loss — executed and gated.

ISSUE 7 tentpole: the injection -> detection -> adaptation loop priced end
to end, with the three study conclusions as hard gates (not just numbers):

* **brownout** — under a 4x bandwidth brownout on one DC pair, the
  :class:`~repro.scenario.spec.DegradationPolicy` run finishes strictly
  faster than the no-policy run; the SLA probe trips inside its
  ``trip_after`` hysteresis window; and *no* BFD recovery timeline exists
  in either run (gray failure by construction: the links never go down);
* **SRLG atomicity** — a ``fiber_cut`` fails every member link through
  one shared detection window, and the resulting routing + control-plane
  state (per-link reroute stats, EVPN resync stats, and the costed
  schedule's per-link byte counters) is byte-for-byte identical to
  sequential per-link failure in the same order — the incremental
  re-converger composes;
* **pod-loss economics** — the priced recovery decomposes exactly:
  ``lost_work = (detected_step - last pre-failure checkpoint) * step_time``
  and ``total = lost_work + detect + restore + remesh``, with the downtime
  charged to precisely the detection step of the timeline;
* the degradation/storm campaign axes are worker-invariant: a 2-worker
  process-pool run joins to the identical table.

Every run's deterministic ``metrics()`` land as gated ``BenchRow`` rows
(``BENCH_resilience.json``) under ``benchmarks/compare.py``.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

from repro.scenario import get_scenario, random_campaign, run_scenario, run_sweep

from .common import BenchRow, timed

CAMPAIGN_SEED = 17


def _srlg_member_links(geo, pairs) -> List[Tuple[str, str]]:
    """The WAN links a fiber_cut severs, in the runner's sorted order."""
    members = set(pairs)
    return sorted(
        tuple(sorted(l))
        for l in geo.fabric.wan_links
        if geo.fabric.wan_pair(*l) in members and geo.fabric.link_up(*l)
    )


def run() -> List[BenchRow]:
    rows: List[BenchRow] = []

    # -- gate: graceful degradation beats riding out the brownout ------------
    with_policy, us_p = timed(lambda: run_scenario(get_scenario("wan_brownout")))
    no_policy, us_n = timed(
        lambda: run_scenario(get_scenario("wan_brownout", policy=None))
    )
    if with_policy.recoveries or no_policy.recoveries:
        raise AssertionError(
            "brownout must be gray: BFD produced a recovery timeline"
        )
    if not with_policy.total_seconds < no_policy.total_seconds:
        raise AssertionError(
            f"policy run ({with_policy.total_seconds:.3f}s) must beat "
            f"no-policy ({no_policy.total_seconds:.3f}s) under the brownout"
        )
    policy = with_policy.scenario.policy
    degrade_at = next(
        e.at_step for e in with_policy.scenario.events if e.kind == "degrade_pair"
    )
    first_trip_ms = with_policy.metrics()["probe_first_trip_ms"]
    # probe clock runs at 1000 ms/step; the trip must land exactly at the
    # trip_after-th breaching observation (hysteresis window, no earlier)
    expected_trip_ms = (degrade_at + policy.trip_after - 1) * 1000.0
    if first_trip_ms != expected_trip_ms:
        raise AssertionError(
            f"probe tripped at {first_trip_ms}ms, expected {expected_trip_ms}ms "
            f"(degrade at step {degrade_at}, trip_after={policy.trip_after})"
        )
    rows.append(
        BenchRow(
            name="brownout_policy",
            us_per_call=us_p,
            derived=(
                f"total {with_policy.total_seconds:.2f}s (no-policy "
                f"{no_policy.total_seconds:.2f}s), trip@{first_trip_ms:.0f}ms, "
                f"BFD quiet"
            ),
            metrics=with_policy.metrics(),
        )
    )
    rows.append(
        BenchRow(
            name="brownout_no_policy",
            us_per_call=us_n,
            derived="same brownout ridden at full cost",
            metrics=no_policy.metrics(),
        )
    )

    # -- gate: SRLG fiber cut == sequential per-link failure, byte for byte --
    spec = get_scenario("srlg_fiber_cut")
    pairs = spec.topology.srlg_pairs("subsea-1")
    geo_group = spec.topology.build()
    geo_seq = spec.topology.build()
    links = _srlg_member_links(geo_group, pairs)
    if len(links) < 2 or len({geo_group.fabric.wan_pair(*l) for l in links}) < 2:
        raise AssertionError("SRLG gate needs links spanning multiple DC pairs")
    _, group_reroutes, group_resyncs = geo_group.detector.fail_group(links)
    seq_reroutes = [geo_seq.fabric.fail_link(*l) for l in links]
    seq_resyncs = [geo_seq.evpn.resync_incremental(s) for s in seq_reroutes]
    if [dataclasses.asdict(s) for s in group_reroutes] != [
        dataclasses.asdict(s) for s in seq_reroutes
    ]:
        raise AssertionError("SRLG group reroute stats differ from sequential")
    if [dataclasses.asdict(s) for s in group_resyncs] != [
        dataclasses.asdict(s) for s in seq_resyncs
    ]:
        raise AssertionError("SRLG group EVPN resyncs differ from sequential")
    grad = spec.workload.resolve_grad_bytes()
    cost_group = geo_group.sync_cost("hier", grad, jitter=False)
    cost_seq = geo_seq.sync_cost("hier", grad, jitter=False)
    if dict(geo_group.fabric.link_bytes) != dict(geo_seq.fabric.link_bytes):
        raise AssertionError("post-cut routed byte counters differ")
    if cost_group.wan_seconds != cost_seq.wan_seconds:
        raise AssertionError(
            f"post-cut sync costs differ: group {cost_group.wan_seconds} "
            f"vs sequential {cost_seq.wan_seconds}"
        )
    srlg_result, us_s = timed(lambda: run_scenario(get_scenario("srlg_fiber_cut")))
    if len(srlg_result.recoveries) != 1:
        raise AssertionError(
            f"one fiber_cut must yield one shared detection timeline, got "
            f"{len(srlg_result.recoveries)}"
        )
    if len(srlg_result.reroutes) != 2 * len(links):
        raise AssertionError("expected one reroute per member link, cut + restore")
    rows.append(
        BenchRow(
            name="srlg_fiber_cut",
            us_per_call=us_s,
            derived=(
                f"{len(links)} links over {len(pairs)} DC pairs, one shared "
                f"detection ({srlg_result.recoveries[0].recovery_ms:.0f}ms); "
                f"state == sequential, post-cut sync {cost_group.wan_seconds:.3f}s"
            ),
            metrics=srlg_result.metrics(),
        )
    )

    # -- gate: pod-loss lost work decomposes exactly --------------------------
    pod_result, us_pod = timed(
        lambda: run_scenario(get_scenario("pod_loss_recovery"))
    )
    if len(pod_result.pod_recoveries) != 1:
        raise AssertionError("expected exactly one priced pod recovery")
    rec = pod_result.pod_recoveries[0]
    pricing = pod_result.scenario.policy
    checkpoint = (rec.failed_at_step // pricing.checkpoint_every) * pricing.checkpoint_every
    if rec.plan.lost_steps != rec.detected_at_step - checkpoint:
        raise AssertionError(
            f"lost_steps {rec.plan.lost_steps} != detection "
            f"{rec.detected_at_step} - checkpoint {checkpoint}"
        )
    m = pod_result.metrics()
    decomposed = m["pod_lost_work_seconds"] + m["pod_downtime_seconds"]
    if abs(m["pod_total_cost_seconds"] - decomposed) > 1e-9:
        raise AssertionError(
            f"total cost {m['pod_total_cost_seconds']} != lost work + downtime "
            f"{decomposed}"
        )
    downtime_steps = [s.step for s in pod_result.steps if s.downtime_seconds > 0]
    if downtime_steps != [rec.detected_at_step]:
        raise AssertionError(
            f"downtime must be charged to the detection step "
            f"{rec.detected_at_step}, found on {downtime_steps}"
        )
    rows.append(
        BenchRow(
            name="pod_loss_recovery",
            us_per_call=us_pod,
            derived=(
                f"pod {rec.pod} died@{rec.failed_at_step} "
                f"detected@{rec.detected_at_step}, {rec.plan.lost_steps} steps "
                f"lost, downtime {rec.plan.total_downtime_s:.2f}s, "
                f"mesh -> {rec.mesh.note}"
            ),
            metrics=m,
        )
    )

    # -- gate: degradation/storm campaign axes are worker-invariant ----------
    def _campaign():
        return random_campaign(
            seed=CAMPAIGN_SEED,
            variants=4,
            degrade_probability=0.7,
            storm_probability=0.5,
        )

    mc, us_mc = timed(lambda: run_sweep(_campaign()))
    mc_par = run_sweep(_campaign(), workers=2)
    if [r.to_dict() for r in mc.rows] != [r.to_dict() for r in mc_par.rows]:
        raise AssertionError(
            "degradation campaign differs between serial and 2-worker runs"
        )
    kinds = {e.kind for v in _campaign().variants() for e in v.events}
    if "degrade_pair" not in kinds or "fail_switch" not in kinds:
        raise AssertionError(
            f"campaign seed {CAMPAIGN_SEED} must exercise both new axes, got {kinds}"
        )
    for r in mc.rows:
        rows.append(
            BenchRow(
                name=f"degrade_campaign_{r.name}",
                us_per_call=us_mc / len(mc.rows),
                derived=f"{len(r.overrides)} overrides",
                metrics=dict(r.metrics),
            )
        )
    return rows
