"""Fig. 8 reproduction: host-to-host RTT under netem WAN emulation.

Paper: 5 ms delay + 1 ms jitter per WAN interface -> ~22 ms RTT between
d1h1 and d2h1 with visible jitter.
"""

from __future__ import annotations


from repro.core.fabric import Fabric
from repro.core.wan import Netem, ping_rtt

from .common import BenchRow, timed


def run() -> list[BenchRow]:
    fabric = Fabric()
    netem = Netem(fabric, seed=8)
    samples, us = timed(lambda: ping_rtt(netem, "d1h1", "d2h1", count=200))
    inter = BenchRow(
        name="fig8_rtt_inter_dc_ms",
        us_per_call=us / 200,
        derived=(
            f"mean={samples.mean():.2f}ms std={samples.std():.2f} "
            f"min={samples.min():.1f} max={samples.max():.1f} (paper ~22ms)"
        ),
    )
    intra_s, us2 = timed(lambda: ping_rtt(netem, "d1h3", "d1h5", count=100))
    intra = BenchRow(
        name="fig8_rtt_intra_dc_ms",
        us_per_call=us2 / 100,
        derived=f"mean={intra_s.mean():.3f}ms (paper ~0.07ms scale)",
    )
    assert 20.0 < samples.mean() < 24.0, "inter-DC RTT out of paper band"
    return [inter, intra]
