"""Markdown intra-repo link checker (ISSUE 9 docs CI job).

Scans markdown files for ``[text](target)`` links and ``#`` heading
anchors and fails on dead *intra-repo* references:

* a relative path target that does not exist on disk;
* a ``path#anchor`` (or same-file ``#anchor``) whose anchor matches no
  heading in the target file (GitHub-style slugs);
* external targets (``http://``, ``https://``, ``mailto:``) are ignored
  — CI must not depend on the network.

Stdlib only.  Usage::

    python tools/check_links.py README.md docs/
    python tools/check_links.py            # defaults to README.md + docs/

Exit status 0 when every link resolves, 1 otherwise (one line per dead
link: ``file:line: dead link -> target (reason)``).
"""

from __future__ import annotations

import pathlib
import re
import sys
from typing import Iterable, List, Set, Tuple

# [text](target) — target up to the first unescaped ')'; images too
_LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")
_CODE_FENCE_RE = re.compile(r"^(```|~~~)")
_EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop non-word/space/hyphen chars
    (backticks, punctuation), spaces to hyphens."""
    text = re.sub(r"[`*_~]", "", heading.strip())
    text = re.sub(r"[^\w\- ]", "", text.lower())
    return text.replace(" ", "-")


def heading_anchors(md_path: pathlib.Path) -> Set[str]:
    """All GitHub-style anchors a markdown file defines (duplicate
    headings get ``-1``, ``-2``, ... suffixes, like GitHub)."""
    seen: dict = {}
    anchors: Set[str] = set()
    in_fence = False
    for line in md_path.read_text(encoding="utf-8").splitlines():
        if _CODE_FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = _HEADING_RE.match(line)
        if not m:
            continue
        slug = github_slug(m.group(2))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        anchors.add(slug if n == 0 else f"{slug}-{n}")
    return anchors


def iter_links(md_path: pathlib.Path) -> Iterable[Tuple[int, str]]:
    """(line_number, target) for every markdown link, skipping fenced
    code blocks and inline code spans."""
    in_fence = False
    for lineno, line in enumerate(
        md_path.read_text(encoding="utf-8").splitlines(), start=1
    ):
        if _CODE_FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        stripped = re.sub(r"`[^`]*`", "", line)  # inline code spans
        for m in _LINK_RE.finditer(stripped):
            yield lineno, m.group(1)


def check_file(md_path: pathlib.Path, repo_root: pathlib.Path) -> List[str]:
    errors: List[str] = []
    for lineno, target in iter_links(md_path):
        if target.startswith(_EXTERNAL):
            continue
        path_part, _, anchor = target.partition("#")
        if path_part:
            dest = (md_path.parent / path_part).resolve()
            try:
                dest.relative_to(repo_root)
            except ValueError:
                errors.append(
                    f"{md_path}:{lineno}: dead link -> {target} "
                    "(escapes the repository)"
                )
                continue
            if not dest.exists():
                errors.append(
                    f"{md_path}:{lineno}: dead link -> {target} (no such file)"
                )
                continue
        else:
            dest = md_path
        if anchor:
            if dest.is_dir() or dest.suffix.lower() not in (".md", ".markdown"):
                continue  # anchors into non-markdown: nothing to verify
            if github_slug(anchor) not in heading_anchors(dest):
                errors.append(
                    f"{md_path}:{lineno}: dead link -> {target} "
                    f"(no heading for #{anchor})"
                )
    return errors


def collect(paths: List[str]) -> List[pathlib.Path]:
    files: List[pathlib.Path] = []
    for p in paths:
        path = pathlib.Path(p)
        if path.is_dir():
            files.extend(sorted(path.rglob("*.md")))
        else:
            files.append(path)
    return files


def main(argv: List[str]) -> int:
    targets = argv or ["README.md", "docs"]
    repo_root = pathlib.Path.cwd().resolve()
    errors: List[str] = []
    files = collect(targets)
    for md in files:
        if not md.exists():
            errors.append(f"{md}: no such file")
            continue
        errors.extend(check_file(md, repo_root))
    for e in errors:
        print(e)
    print(
        f"checked {len(files)} file(s): "
        + ("OK" if not errors else f"{len(errors)} dead link(s)")
    )
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
