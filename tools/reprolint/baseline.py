"""Ratchet baseline: grandfathered findings that may only shrink.

The committed ``tools/reprolint/baseline.json`` lists findings that
predate a rule (fingerprinted line-number-independently as
``(rule, path, context)``).  Semantics:

* a current finding matching a baseline entry is *grandfathered* (does
  not fail the run);
* a current finding with no entry is **new** — the run fails;
* a baseline entry matching no current finding is **stale** — the run
  also fails, with instructions to shrink the baseline
  (``--write-baseline``), so the ratchet only ever tightens;
* ``--ratchet REF`` additionally proves the committed baseline is a
  subset of the one at a git ref (CI runs it against the PR base), so
  entries can be removed but never added back.
"""

from __future__ import annotations

import json
import pathlib
import subprocess
from collections import Counter
from typing import Dict, List, Optional, Sequence, Tuple

from .core import Finding

DEFAULT_BASELINE = "tools/reprolint/baseline.json"

Key = Tuple[str, str, str]


def _keys(entries: Sequence[Dict[str, str]]) -> Counter:
    return Counter(
        (e["rule"], e["path"], e.get("context", "")) for e in entries
    )


def load(path: pathlib.Path) -> List[Dict[str, str]]:
    data = json.loads(path.read_text(encoding="utf-8"))
    if not isinstance(data, dict) or "findings" not in data:
        raise ValueError(f"{path}: not a reprolint baseline (no 'findings')")
    return data["findings"]


def dump(findings: Sequence[Finding], path: pathlib.Path) -> None:
    entries = [
        {"rule": f.rule, "path": f.path, "context": f.context}
        for f in sorted(findings, key=lambda f: f.key)
    ]
    path.write_text(
        json.dumps({"version": 1, "findings": entries}, indent=1) + "\n",
        encoding="utf-8",
    )


def split(
    findings: Sequence[Finding], entries: Sequence[Dict[str, str]]
) -> Tuple[List[Finding], List[Finding], List[Key]]:
    """(new, grandfathered, stale_keys) under multiset matching."""
    budget = _keys(entries)
    new: List[Finding] = []
    grandfathered: List[Finding] = []
    for f in findings:
        if budget.get(f.key, 0) > 0:
            budget[f.key] -= 1
            grandfathered.append(f)
        else:
            new.append(f)
    stale = sorted(budget.elements())
    return new, grandfathered, stale


def at_git_ref(ref: str, repo_root: pathlib.Path) -> Optional[List[Dict[str, str]]]:
    """Baseline entries at ``REF:tools/reprolint/baseline.json``, or
    ``None`` when the file does not exist there — the PR that introduces
    the baseline has nothing to ratchet against, so the check is skipped
    rather than treating "no baseline yet" as an empty one it grew from."""
    proc = subprocess.run(
        ["git", "show", f"{ref}:{DEFAULT_BASELINE}"],
        cwd=repo_root,
        capture_output=True,
        text=True,
    )
    if proc.returncode != 0:
        return None
    return json.loads(proc.stdout)["findings"]


def ratchet_errors(
    current: Sequence[Dict[str, str]], old: Sequence[Dict[str, str]]
) -> List[str]:
    """Entries present now but absent at the ref — the ratchet only
    shrinks, so each is an error."""
    grown = _keys(current) - _keys(old)
    return [
        f"baseline grew: {rule} at {path} ({context!r}) is not in the base "
        "ref's baseline — fix the finding instead of grandfathering it"
        for (rule, path, context), n in sorted(grown.items())
        for _ in range(n)
    ]
