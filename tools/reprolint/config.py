"""Declarative configuration for the reprolint rules.

Everything the rules enforce is *declared here*, in one place, so the
invariants documented in ``docs/ARCHITECTURE.md`` (layer map,
determinism discipline, spec contracts, oracle retention) have exactly
one machine-readable source of truth.  Changing an invariant means
editing this file — a reviewable, greppable diff — not weakening a rule.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

# --------------------------------------------------------------------------
# Layer DAG (docs/ARCHITECTURE.md#layer-map)
#
# Dotted module names (longest prefix wins) -> layer index.  Dependencies
# must point downward: a module may import same-or-lower layers only.
# Modules without an assignment (benchmarks, tests, examples, the
# executable JAX stack) are not layered — the DAG rule ignores their own
# imports, but `sibling-stack` still guards the boundary *into* them.
# --------------------------------------------------------------------------

LAYER_NAMES: Dict[int, str] = {
    0: "fabric",
    1: "congestion/schedule",
    2: "scenario",
    3: "sweep/resilience/serving",
}

LAYER_OF: Dict[str, int] = {
    # fabric: the emulated EVPN-VXLAN spine-leaf WAN
    "repro.core.fabric": 0,
    "repro.core.evpn": 0,
    "repro.core.bfd": 0,
    "repro.core.flows": 0,
    "repro.core.ports": 0,
    "repro.core.collision": 0,
    "repro.core.metrics": 0,
    "repro.core.tenancy": 0,
    # congestion / schedule: allocators, phase DAGs, netem resolution,
    # detection primitives, and the GeoFabric facade over them
    "repro.core.congestion": 1,
    "repro.core.schedule": 1,
    "repro.core.wan": 1,
    "repro.core.slaprobe": 1,  # leaf detection primitive; the resilience *loop* is layer 3
    "repro.core.geo": 1,
    # the package surface re-exports everything in core (layers 0-1)
    "repro.core": 1,
    # scenario: declarative spec + runner + named library
    "repro.scenario.spec": 2,
    "repro.scenario.runner": 2,
    "repro.scenario.library": 2,
    # sweep / resilience / serving: subsystems that drive scenarios
    "repro.scenario.sweep": 3,
    "repro.scenario": 3,  # package surface re-exports sweep
    "repro.serving": 3,
}


def layer_of(module: str) -> Optional[int]:
    """Longest-dotted-prefix layer lookup; ``None`` when unlayered."""
    parts = module.split(".")
    for i in range(len(parts), 0, -1):
        layer = LAYER_OF.get(".".join(parts[:i]))
        if layer is not None:
            return layer
    return None


# --------------------------------------------------------------------------
# Sibling stack (docs/ARCHITECTURE.md#layer-map, closing paragraph)
#
# The executable JAX training stack sits *beside* the simulator layers,
# not below them: simulator modules must stay importable (and sweep
# workers spawnable) without jax.  Layered modules may only reach these
# packages through function-level (lazy) imports.
# --------------------------------------------------------------------------

SIBLING_STACK: Tuple[str, ...] = (
    "repro.models",
    "repro.kernels",
    "repro.runtime",
    "repro.distributed",
    "repro.optim",
    "repro.launch",
    "repro.checkpoint",
    "repro.configs",
    "repro.data",
    "repro.testing",
)

#: Heavyweight external packages the simulator layers must not import at
#: module level (same lazy-import discipline as the sibling stack).
HEAVY_EXTERNAL: Tuple[str, ...] = ("jax", "flax", "jaxlib")


# --------------------------------------------------------------------------
# Determinism discipline (docs/ARCHITECTURE.md — byte-identity gates)
#
# Simulator layers must be a pure function of their inputs: no wall
# clock, no ambient RNG state, no unseeded generators, no iteration over
# unordered sets.  The executable stack measures real wall time and
# draws real randomness — that is its job — so it is allowlisted.
# --------------------------------------------------------------------------

#: Modules the wall-clock rule scans (prefix match).
WALL_CLOCK_SCOPE: Tuple[str, ...] = ("repro",)

#: Allowlisted prefixes: the executable stack legitimately reads the
#: clock (step timing, CLI progress).  ``repro.checkpoint`` is *not*
#: allowlisted — its wall-clock dependence is injected through the
#: ``clock=time.time`` seam, which the rule permits because only *calls*
#: are flagged, never references (a default-parameter value is the seam).
WALL_CLOCK_ALLOW: Tuple[str, ...] = ("repro.launch", "repro.runtime")

#: Wall-clock callables (post alias-resolution dotted names) that must
#: not be *called* in scope.
WALL_CLOCK_BANNED: Tuple[str, ...] = (
    "time.time",
    "time.time_ns",
    "time.monotonic",
    "time.monotonic_ns",
    "time.perf_counter",
    "time.perf_counter_ns",
    "datetime.datetime.now",
    "datetime.datetime.utcnow",
    "datetime.datetime.today",
    "datetime.date.today",
)

#: Simulator layers scanned by the RNG and set-iteration rules.
DETERMINISM_SCOPE: Tuple[str, ...] = (
    "repro.core",
    "repro.scenario",
    "repro.serving",
)

#: Per-rule allowlist (issue contract): the launch/runtime/checkpoint
#: modules may use ambient randomness (e.g. jitter in real retries).
DETERMINISM_ALLOW: Tuple[str, ...] = (
    "repro.launch",
    "repro.runtime",
    "repro.checkpoint",
)

#: ``numpy.random`` module-level functions that mutate/read the *global*
#: legacy RNG state — banned in simulator layers (use a seeded
#: ``default_rng(seed)`` Generator instead).
AMBIENT_NP_RANDOM: Tuple[str, ...] = (
    "seed",
    "random",
    "rand",
    "randn",
    "randint",
    "random_integers",
    "random_sample",
    "ranf",
    "sample",
    "choice",
    "bytes",
    "shuffle",
    "permutation",
    "uniform",
    "normal",
    "standard_normal",
    "poisson",
    "exponential",
    "binomial",
    "lognormal",
    "pareto",
    "get_state",
    "set_state",
)

#: stdlib ``random`` module-level functions (global ``Random`` instance).
#: ``random.Random(seed)`` / ``random.SystemRandom`` constructions are
#: fine — only the ambient module-level state is banned.
AMBIENT_PY_RANDOM: Tuple[str, ...] = (
    "seed",
    "random",
    "randint",
    "randrange",
    "randbytes",
    "getrandbits",
    "choice",
    "choices",
    "shuffle",
    "sample",
    "uniform",
    "triangular",
    "gauss",
    "normalvariate",
    "lognormvariate",
    "expovariate",
    "betavariate",
    "paretovariate",
)


# --------------------------------------------------------------------------
# Spec contracts (scenario JSON round-trip discipline)
# --------------------------------------------------------------------------

#: Class-name suffixes that mark a declarative spec dataclass.
SPEC_SUFFIXES: Tuple[str, ...] = ("Spec", "Options")

#: Modules scanned by the spec-contract rules (prefix match).
SPEC_SCOPE: Tuple[str, ...] = ("repro",)


# --------------------------------------------------------------------------
# Oracle retention (docs/ARCHITECTURE.md#the-byte-identity-gate-convention)
#
# Every fast path keeps its from-scratch oracle selectable forever.  A
# def/class whose name contains "incremental" or ends in "_batched" is a
# declared fast path; it must have an entry here, and every symbol the
# entry names must still be defined in the same module.  Deleting
# ``_FullEpochAllocator`` (or the sequential walk) is a lint error, not
# an archaeology exercise.
# --------------------------------------------------------------------------

ORACLE_MAP: Dict[str, Dict[str, Sequence[str]]] = {
    "repro.core.congestion": {
        # warm-started event-loop allocator vs the from-scratch oracle,
        # selectable via simulate_schedule(..., incremental=False)
        "_IncrementalAllocator": ("_FullEpochAllocator", "INCREMENTAL_EVENT_LOOP"),
    },
    "repro.core.fabric": {
        # vectorized CRC router vs the sequential per-flow walk
        "route_flows_batched": ("route_flow",),
    },
    "repro.core.flows": {
        # batched module-level wrapper vs the sequential route_flows loop
        "route_flows_batched": ("route_flows",),
    },
    "repro.core.evpn": {
        # incremental EVPN resync vs the full-resync oracle
        "resync_incremental": ("resync",),
    },
}

#: Modules scanned by the oracle-retention rule (prefix match).
ORACLE_SCOPE: Tuple[str, ...] = ("repro",)
