"""The reprolint rule set — the machine-checked form of
``docs/ARCHITECTURE.md``'s invariants.

Each rule is module-scoped (sees one parsed file at a time) and
declaratively configured in :mod:`tools.reprolint.config`:

========================  ====================================================
``layer-dag``             upward import in the simulator layer DAG
``sibling-stack``         simulator module imports the JAX stack eagerly
``wall-clock``            wall-clock *call* in a deterministic module
``rng-discipline``        unseeded ``default_rng()`` / ambient RNG state
``set-iteration``         loop or comprehension iterates a bare set
``spec-frozen``           ``*Spec``/``*Options`` dataclass not frozen
``spec-from-dict``        spec dataclass without a ``from_dict``
``from-dict-strict``      ``from_dict`` body cannot reject unknown keys
``oracle-retention``      fast path whose documented oracle is gone
========================  ====================================================

(Plus the engine-level ``unused-suppression`` accounting rule.)
"""

from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Set

from . import config
from .core import Finding, ModuleInfo, Rule, dotted_name, in_scope, register


# -- layering ----------------------------------------------------------------


def _import_targets(module: ModuleInfo) -> Iterable[tuple]:
    """(node, absolute dotted target) for every eager import."""
    for node, target, level in module.eager_imports():
        base = module.resolve_relative(target, level)
        if isinstance(node, ast.ImportFrom):
            # `from X import a` may pull a submodule: attribute the
            # import to X.a when that has its own layer assignment
            # (e.g. `from repro.scenario import sweep`), else to X.
            for a in node.names:
                if a.name == "*":
                    yield node, base
                    continue
                sub = f"{base}.{a.name}" if base else a.name
                yield node, (sub if sub in config.LAYER_OF else base)
        else:
            yield node, base


@register
class LayerDagRule(Rule):
    id = "layer-dag"
    description = (
        "Dependencies in the simulator stack point downward only: "
        "fabric <- congestion/schedule <- scenario <- "
        "sweep/resilience/serving (docs/ARCHITECTURE.md#layer-map)."
    )

    def check(self, module: ModuleInfo) -> Iterable[Finding]:
        my_layer = config.layer_of(module.module)
        if my_layer is None:
            return
        for node, target in _import_targets(module):
            target_layer = config.layer_of(target)
            if target_layer is None or target_layer <= my_layer:
                continue
            yield module.finding(
                self.id,
                node,
                f"upward import: {module.module} "
                f"(layer {my_layer}, {config.LAYER_NAMES[my_layer]!r}) imports "
                f"{target} (layer {target_layer}, "
                f"{config.LAYER_NAMES[target_layer]!r}); dependencies must "
                "point downward — move the import below the consumer or "
                "make it lazy (function-level)",
            )


@register
class SiblingStackRule(Rule):
    id = "sibling-stack"
    description = (
        "Simulator layers never import the executable JAX stack "
        "(repro.models/kernels/runtime/... or jax itself) at module "
        "level; sweep workers must stay importable without jax."
    )

    def check(self, module: ModuleInfo) -> Iterable[Finding]:
        if config.layer_of(module.module) is None:
            return
        banned = config.SIBLING_STACK + config.HEAVY_EXTERNAL
        for node, target in _import_targets(module):
            if not in_scope(target, banned):
                continue
            yield module.finding(
                self.id,
                node,
                f"simulator module {module.module} imports {target} at module "
                "level; the JAX stack is a sibling, not a lower layer — "
                "import it inside the function that needs it",
            )


# -- determinism -------------------------------------------------------------


@register
class WallClockRule(Rule):
    id = "wall-clock"
    description = (
        "No wall-clock reads in deterministic modules: time.time() & co. "
        "make replays diverge.  References are allowed (an injectable "
        "`clock=time.time` default parameter is the sanctioned seam); "
        "only calls are flagged."
    )

    def check(self, module: ModuleInfo) -> Iterable[Finding]:
        if not in_scope(module.module, config.WALL_CLOCK_SCOPE):
            return
        if in_scope(module.module, config.WALL_CLOCK_ALLOW):
            return
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            target = module.call_target(node)
            if target in config.WALL_CLOCK_BANNED:
                yield module.finding(
                    self.id,
                    node,
                    f"wall-clock call {target}() in deterministic module "
                    f"{module.module}; inject a clock (default-parameter "
                    "reference is fine) or take the timestamp as an argument",
                )


@register
class RngDisciplineRule(Rule):
    id = "rng-discipline"
    description = (
        "Simulator randomness flows through seeded Generators: no "
        "unseeded np.random.default_rng(), no ambient random.* / "
        "np.random.* global-state calls."
    )

    def check(self, module: ModuleInfo) -> Iterable[Finding]:
        if not in_scope(module.module, config.DETERMINISM_SCOPE):
            return
        if in_scope(module.module, config.DETERMINISM_ALLOW):
            return
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            target = module.call_target(node)
            if target is None:
                continue
            msg = self._classify(target, node)
            if msg:
                yield module.finding(self.id, node, msg)

    @staticmethod
    def _classify(target: str, call: ast.Call) -> Optional[str]:
        if target in ("numpy.random.default_rng", "numpy.random.RandomState"):
            unseeded = not call.args and not call.keywords
            if not unseeded and call.args:
                unseeded = isinstance(call.args[0], ast.Constant) and (
                    call.args[0].value is None
                )
            if unseeded:
                return (
                    f"unseeded {target}(): entropy comes from the OS, "
                    "every run differs — thread an explicit seed"
                )
            return None
        head, _, fn = target.rpartition(".")
        if head == "numpy.random" and fn in config.AMBIENT_NP_RANDOM:
            return (
                f"ambient global-state RNG call {target}(); use a seeded "
                "np.random.default_rng(seed) Generator instead"
            )
        if head == "random" and fn in config.AMBIENT_PY_RANDOM:
            return (
                f"ambient global-state RNG call {target}(); use a seeded "
                "random.Random(seed) instance instead"
            )
        return None


@register
class SetIterationRule(Rule):
    id = "set-iteration"
    description = (
        "Loops and comprehensions must not iterate a bare set: str hashes "
        "are salted per process, so set order varies across workers and "
        "breaks worker-count invariance.  Wrap in sorted(...)."
    )

    #: one-level wrappers that preserve the underlying set order
    _ORDER_PRESERVING = ("list", "tuple", "enumerate", "reversed", "iter")

    def check(self, module: ModuleInfo) -> Iterable[Finding]:
        if not in_scope(module.module, config.DETERMINISM_SCOPE):
            return
        if in_scope(module.module, config.DETERMINISM_ALLOW):
            return
        for node in ast.walk(module.tree):
            iters: List[ast.AST] = []
            if isinstance(node, (ast.For, ast.AsyncFor)):
                iters = [node.iter]
            elif isinstance(
                node, (ast.ListComp, ast.SetComp, ast.DictComp, ast.GeneratorExp)
            ):
                iters = [g.iter for g in node.generators]
            for it in iters:
                if self._is_bare_set(it):
                    yield module.finding(
                        self.id,
                        it,
                        "iteration over a bare set expression: order is "
                        "process-dependent for str/object elements — wrap "
                        "in sorted(...) (or suppress where order provably "
                        "cannot leak into results)",
                    )

    @classmethod
    def _is_bare_set(cls, node: ast.AST) -> bool:
        if isinstance(node, ast.Call):
            name = dotted_name(node.func)
            if name in ("set", "frozenset"):
                return True
            if name in cls._ORDER_PRESERVING and node.args:
                return cls._is_bare_set(node.args[0])
            return False
        return isinstance(node, (ast.Set, ast.SetComp))


# -- spec contracts ----------------------------------------------------------


def _dataclass_decorator(cls: ast.ClassDef) -> Optional[ast.AST]:
    for dec in cls.decorator_list:
        name = dotted_name(dec.func if isinstance(dec, ast.Call) else dec)
        if name in ("dataclass", "dataclasses.dataclass"):
            return dec
    return None


def _is_spec_class(cls: ast.ClassDef) -> bool:
    return cls.name.endswith(config.SPEC_SUFFIXES) and not cls.name.startswith("_")


def _spec_dataclasses(module: ModuleInfo):
    for node in module.tree.body:
        if isinstance(node, ast.ClassDef) and _is_spec_class(node):
            dec = _dataclass_decorator(node)
            if dec is not None:
                yield node, dec


@register
class SpecFrozenRule(Rule):
    id = "spec-frozen"
    description = (
        "Every *Spec/*Options dataclass is frozen=True: specs are hashed, "
        "shared across sweep workers, and replaced — never mutated."
    )

    def check(self, module: ModuleInfo) -> Iterable[Finding]:
        if not in_scope(module.module, config.SPEC_SCOPE):
            return
        for cls, dec in _spec_dataclasses(module):
            frozen = isinstance(dec, ast.Call) and any(
                kw.arg == "frozen"
                and isinstance(kw.value, ast.Constant)
                and kw.value.value is True
                for kw in dec.keywords
            )
            if not frozen:
                yield module.finding(
                    self.id,
                    cls,
                    f"spec dataclass {cls.name} is not frozen=True; declare "
                    "@dataclass(frozen=True) so instances are immutable "
                    "and hashable",
                )


@register
class SpecFromDictRule(Rule):
    id = "spec-from-dict"
    description = (
        "Every *Spec/*Options dataclass round-trips through a strict "
        "from_dict (on the class or at module level) so sweep overrides "
        "and JSON replay cannot silently drop fields."
    )

    def check(self, module: ModuleInfo) -> Iterable[Finding]:
        if not in_scope(module.module, config.SPEC_SCOPE):
            return
        module_level = {
            n.name
            for n in module.tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        }
        for cls, _ in _spec_dataclasses(module):
            has_method = any(
                isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                and n.name == "from_dict"
                for n in cls.body
            )
            if not has_method and "from_dict" not in module_level:
                yield module.finding(
                    self.id,
                    cls,
                    f"spec dataclass {cls.name} has no from_dict in "
                    f"{module.module}; define a strict classmethod "
                    "from_dict(cls, d) that rejects unknown keys",
                )


@register
class FromDictStrictRule(Rule):
    id = "from-dict-strict"
    description = (
        "from_dict bodies reject unknown keys (call _reject_unknown_keys "
        "or raise explicitly): a typo'd sweep override must be an error, "
        "not a silently-ignored field."
    )

    def check(self, module: ModuleInfo) -> Iterable[Finding]:
        if not in_scope(module.module, config.SPEC_SCOPE):
            return
        for node in ast.walk(module.tree):
            if (
                isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                and node.name == "from_dict"
                and not self._is_strict(node)
            ):
                yield module.finding(
                    self.id,
                    node,
                    f"from_dict in {module.module} never rejects unknown "
                    "keys; call _reject_unknown_keys(cls, d) (or compare "
                    "against dataclasses.fields and raise)",
                )

    @staticmethod
    def _is_strict(fn: ast.AST) -> bool:
        for node in ast.walk(fn):
            if isinstance(node, ast.Raise):
                return True
            if isinstance(node, ast.Call):
                name = dotted_name(node.func) or ""
                if "reject_unknown" in name:
                    return True
        return False


# -- oracle retention --------------------------------------------------------


def _defined_symbols(module: ModuleInfo) -> Set[str]:
    """Top-level and class-body defs/classes/assignments."""
    out: Set[str] = set()

    def scan(body) -> None:
        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                out.add(node.name)
            elif isinstance(node, ast.ClassDef):
                out.add(node.name)
                scan(node.body)
            elif isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        out.add(t.id)
            elif isinstance(node, ast.AnnAssign) and isinstance(
                node.target, ast.Name
            ):
                out.add(node.target.id)

    scan(module.tree.body)
    return out


def _fast_path_defs(module: ModuleInfo):
    """def/class nodes whose name marks a fast path (contains
    'incremental' case-insensitively, or ends in '_batched')."""

    def scan(body):
        for node in body:
            if isinstance(
                node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
            ):
                name = node.name
                if "incremental" in name.lower() or name.endswith("_batched"):
                    yield node
                if isinstance(node, ast.ClassDef):
                    yield from scan(node.body)

    yield from scan(module.tree.body)


@register
class OracleRetentionRule(Rule):
    id = "oracle-retention"
    description = (
        "Fast paths keep their from-scratch oracle selectable forever "
        "(docs/ARCHITECTURE.md#the-byte-identity-gate-convention): every "
        "*Incremental*/*_batched def needs an ORACLE_MAP entry, and the "
        "symbols that entry names must still exist."
    )

    def check(self, module: ModuleInfo) -> Iterable[Finding]:
        if not in_scope(module.module, config.ORACLE_SCOPE):
            return
        declared = config.ORACLE_MAP.get(module.module, {})
        defined = _defined_symbols(module)
        seen_fast: Set[str] = set()
        for node in _fast_path_defs(module):
            seen_fast.add(node.name)
            oracles = declared.get(node.name)
            if oracles is None:
                yield module.finding(
                    self.id,
                    node,
                    f"fast path {node.name} has no oracle declared; add an "
                    "ORACLE_MAP entry in tools/reprolint/config.py naming "
                    "the retained slow-path symbol(s) it is gated against",
                )
                continue
            for oracle in oracles:
                if oracle not in defined:
                    yield module.finding(
                        self.id,
                        node,
                        f"fast path {node.name} declares oracle {oracle!r} "
                        f"but {module.module} no longer defines it; the "
                        "slow path must stay selectable (byte-identity "
                        "gates re-run forever)",
                    )
        # a mapped fast path that vanished while its map entry remains is
        # stale configuration — flag it so the map tracks reality
        for fast in declared:
            if fast not in seen_fast and fast not in defined:
                yield module.finding(
                    self.id,
                    1,
                    f"ORACLE_MAP names fast path {fast!r} but "
                    f"{module.module} no longer defines it; prune the entry "
                    "in tools/reprolint/config.py",
                )
