"""reprolint framework: module model, rule registry, suppression
accounting, and the lint engine.

Stdlib only (``ast`` + ``tokenize``), in the spirit of
``tools/check_links.py``.  Rules live in :mod:`tools.reprolint.rules`,
their configuration in :mod:`tools.reprolint.config`, reporters in
:mod:`tools.reprolint.reporters`, and the ratchet baseline in
:mod:`tools.reprolint.baseline`.

A finding is suppressed by an inline comment on its own line or the
line above::

    rng = np.random.default_rng()  # reprolint: allow[rng-discipline]

Suppressions are *accounted*: an allow-comment that suppresses nothing
is itself a finding (``unused-suppression``), so stale exemptions are
garbage-collected by CI instead of accreting.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import pathlib
import re
import tokenize
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

_ALLOW_RE = re.compile(r"#\s*reprolint:\s*allow\[([A-Za-z0-9_\-, ]+)\]")

#: Rule id of the suppression-accounting pseudo-rule (not suppressible).
UNUSED_SUPPRESSION = "unused-suppression"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str
    path: str  # repo-relative posix path
    line: int
    message: str
    context: str = ""  # stripped source line (baseline fingerprint)

    @property
    def key(self) -> Tuple[str, str, str]:
        """Baseline fingerprint: line-number independent so pure line
        drift never invalidates a grandfathered entry."""
        return (self.rule, self.path, self.context)

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def module_name_for(relpath: str) -> str:
    """Dotted module name for a repo-relative path.

    ``src/`` is the import root (``src/repro/core/fabric.py`` ->
    ``repro.core.fabric``); everything else is rooted at the repo
    (``benchmarks/bench_sweeps.py`` -> ``benchmarks.bench_sweeps``).
    Package ``__init__.py`` files get the package's own name.
    """
    parts = list(pathlib.PurePosixPath(relpath).parts)
    if parts and parts[0] == "src":
        parts = parts[1:]
    if parts[-1] == "__init__.py":
        parts = parts[:-1]
    else:
        parts[-1] = parts[-1][: -len(".py")]
    return ".".join(parts)


def in_scope(module: str, prefixes: Sequence[str]) -> bool:
    """Dotted-prefix membership: ``repro.core.wan`` is in ``repro.core``."""
    return any(module == p or module.startswith(p + ".") for p in prefixes)


class ModuleInfo:
    """A parsed source file plus everything rules need to inspect it."""

    def __init__(self, relpath: str, source: str, module: Optional[str] = None):
        self.relpath = relpath
        self.source = source
        self.module = module if module is not None else module_name_for(relpath)
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=relpath)
        self.suppressions = self._parse_suppressions(source)
        # (line, rule) pairs consumed by a finding — for unused accounting
        self.used_suppressions: Set[Tuple[int, str]] = set()
        self._eager_imports: Optional[List[Tuple[ast.AST, str, int]]] = None
        self._aliases: Optional[Dict[str, str]] = None

    # -- suppressions --------------------------------------------------------

    @staticmethod
    def _parse_suppressions(source: str) -> Dict[int, Set[str]]:
        out: Dict[int, Set[str]] = {}
        try:
            tokens = tokenize.generate_tokens(io.StringIO(source).readline)
            for tok in tokens:
                if tok.type != tokenize.COMMENT:
                    continue
                m = _ALLOW_RE.search(tok.string)
                if m:
                    ids = {r.strip() for r in m.group(1).split(",") if r.strip()}
                    out.setdefault(tok.start[0], set()).update(ids)
        except tokenize.TokenizeError:  # pragma: no cover - ast.parse raised first
            pass
        return out

    def is_suppressed(self, line: int, rule: str) -> bool:
        """True (and mark the suppression used) if an allow-comment for
        ``rule`` sits on ``line`` or the line directly above."""
        if rule == UNUSED_SUPPRESSION:
            return False
        for ln in (line, line - 1):
            if rule in self.suppressions.get(ln, ()):
                self.used_suppressions.add((ln, rule))
                return True
        return False

    # -- source context ------------------------------------------------------

    def context(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""

    def finding(self, rule: str, node_or_line, message: str) -> Finding:
        line = getattr(node_or_line, "lineno", node_or_line)
        return Finding(
            rule=rule,
            path=self.relpath,
            line=line,
            message=message,
            context=self.context(line),
        )

    # -- imports -------------------------------------------------------------

    def eager_imports(self) -> List[Tuple[ast.AST, str, int]]:
        """Module-execution-time imports as ``(node, target, level)``.

        Imports nested in a function/lambda are *lazy* (the repo's
        sanctioned escape hatch: the scenario runner reaches
        ``repro.runtime`` lazily so sweep workers stay jax-free), and
        imports under an ``if TYPE_CHECKING:`` guard never execute —
        both are excluded.  Class-body imports run at module import and
        are included.
        """
        if self._eager_imports is None:
            self._eager_imports = _collect_eager_imports(self.tree)
        return self._eager_imports

    def resolve_relative(self, target: str, level: int) -> str:
        """Absolute dotted name for a ``from . import ...`` target."""
        if level == 0:
            return target
        pkg = self.module.split(".")
        if not self.relpath.endswith("__init__.py"):
            pkg = pkg[:-1]
        base = pkg[: len(pkg) - (level - 1)]
        return ".".join(base + ([target] if target else [])).strip(".")

    def aliases(self) -> Dict[str, str]:
        """Local name -> absolute dotted origin, from *every* import in
        the module (lazy ones included: a call through a lazily-imported
        alias is still a call)."""
        if self._aliases is None:
            out: Dict[str, str] = {}
            for node in ast.walk(self.tree):
                if isinstance(node, ast.Import):
                    for a in node.names:
                        out[a.asname or a.name.split(".")[0]] = (
                            a.name if a.asname else a.name.split(".")[0]
                        )
                elif isinstance(node, ast.ImportFrom):
                    base = self.resolve_relative(node.module or "", node.level)
                    for a in node.names:
                        if a.name == "*":
                            continue
                        origin = f"{base}.{a.name}" if base else a.name
                        out[a.asname or a.name] = origin
            self._aliases = out
        return self._aliases

    def call_target(self, call: ast.Call) -> Optional[str]:
        """Alias-resolved dotted name of a call's callee (``np.random.rand``
        with ``import numpy as np`` -> ``numpy.random.rand``)."""
        dotted = dotted_name(call.func)
        if dotted is None:
            return None
        head, _, rest = dotted.partition(".")
        origin = self.aliases().get(head)
        if origin is None:
            return dotted
        return f"{origin}.{rest}" if rest else origin


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else ``None``."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def _collect_eager_imports(tree: ast.Module) -> List[Tuple[ast.AST, str, int]]:
    out: List[Tuple[ast.AST, str, int]] = []

    def is_type_checking_guard(test: ast.AST) -> bool:
        d = dotted_name(test)
        return d in ("TYPE_CHECKING", "typing.TYPE_CHECKING")

    def visit(node: ast.AST) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            return  # lazy territory
        if isinstance(node, ast.If) and is_type_checking_guard(node.test):
            for child in node.orelse:
                visit(child)
            return
        if isinstance(node, ast.Import):
            for a in node.names:
                out.append((node, a.name, 0))
            return
        if isinstance(node, ast.ImportFrom):
            out.append((node, node.module or "", node.level))
            return
        for child in ast.iter_child_nodes(node):
            visit(child)

    visit(tree)
    return out


# -- rule registry -----------------------------------------------------------


class Rule:
    """Base class: subclass, set ``id``/``description``, implement
    ``check(module) -> iterable of Finding``, decorate with
    :func:`register`."""

    id: str = ""
    description: str = ""

    def check(self, module: ModuleInfo) -> Iterable[Finding]:  # pragma: no cover
        raise NotImplementedError


RULES: List[Rule] = []


def register(cls):
    """Class decorator adding a rule (singleton instance) to the registry."""
    if not cls.id:
        raise ValueError(f"rule {cls.__name__} has no id")
    if any(r.id == cls.id for r in RULES):
        raise ValueError(f"duplicate rule id {cls.id!r}")
    RULES.append(cls())
    return cls


def rule_ids() -> List[str]:
    return [r.id for r in RULES] + [UNUSED_SUPPRESSION]


# -- engine ------------------------------------------------------------------


def lint_module(
    module: ModuleInfo, only: Optional[Sequence[str]] = None
) -> List[Finding]:
    """Run (optionally a subset of) the registry over one module, apply
    suppression accounting, and append unused-suppression findings."""
    findings: List[Finding] = []
    for rule in RULES:
        if only is not None and rule.id not in only:
            continue
        for f in rule.check(module):
            if not module.is_suppressed(f.line, f.rule):
                findings.append(f)
    if only is None or UNUSED_SUPPRESSION in only:
        known = set(rule_ids())
        for ln in sorted(module.suppressions):
            for rid in sorted(module.suppressions[ln]):
                if (ln, rid) in module.used_suppressions:
                    continue
                reason = (
                    "suppresses nothing"
                    if rid in known
                    else f"unknown rule id {rid!r}"
                )
                findings.append(
                    module.finding(
                        UNUSED_SUPPRESSION,
                        ln,
                        f"allow[{rid}] {reason} — remove the comment",
                    )
                )
    return findings


def lint_source(
    source: str,
    relpath: str = "src/repro/example.py",
    module: Optional[str] = None,
    only: Optional[Sequence[str]] = None,
) -> List[Finding]:
    """Lint a source string under a synthetic path/module name (the
    fixture-test entry point)."""
    return lint_module(ModuleInfo(relpath, source, module), only=only)


def collect_files(paths: Sequence[str], root: pathlib.Path) -> List[pathlib.Path]:
    files: List[pathlib.Path] = []
    for p in paths:
        path = root / p
        if path.is_dir():
            files.extend(
                f
                for f in sorted(path.rglob("*.py"))
                if "__pycache__" not in f.parts
            )
        elif path.suffix == ".py":
            files.append(path)
    return files


def lint_paths(
    paths: Sequence[str],
    root: Optional[pathlib.Path] = None,
    only: Optional[Sequence[str]] = None,
) -> List[Finding]:
    """Lint every ``*.py`` under ``paths`` (files or directories),
    returning findings sorted by location."""
    root = root or pathlib.Path.cwd()
    findings: List[Finding] = []
    for f in collect_files(paths, root):
        try:
            relpath = f.relative_to(root).as_posix()
        except ValueError:
            # Outside the repo root (ad-hoc invocation on a scratch tree):
            # relativize from the nearest src/ marker so module-name
            # derivation still works, else fall back to the full path.
            parts = f.as_posix().split("/")
            relpath = (
                "/".join(parts[parts.index("src"):])
                if "src" in parts
                else f.as_posix().lstrip("/")
            )
        source = f.read_text(encoding="utf-8")
        try:
            mod = ModuleInfo(relpath, source)
        except SyntaxError as e:
            findings.append(
                Finding("parse-error", relpath, e.lineno or 1, str(e))
            )
            continue
        findings.extend(lint_module(mod, only=only))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))
