"""CLI driver: ``python -m tools.reprolint [paths...]``.

Exit status 0 when the tree is clean under the committed ratchet
baseline; 1 on new findings, stale baseline entries, or a grown
baseline (``--ratchet REF``).
"""

from __future__ import annotations

import argparse
import pathlib
import sys
from typing import List

from . import baseline as baseline_mod
from .core import RULES, UNUSED_SUPPRESSION, lint_paths
from .reporters import REPORTERS

DEFAULT_PATHS = ["src", "benchmarks", "tests", "examples"]


def main(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.reprolint",
        description="AST-based invariant linter (layer DAG, determinism, "
        "spec contracts, oracle retention).",
    )
    ap.add_argument(
        "paths",
        nargs="*",
        default=DEFAULT_PATHS,
        help=f"files/directories to lint (default: {' '.join(DEFAULT_PATHS)})",
    )
    ap.add_argument(
        "--format",
        choices=sorted(REPORTERS),
        default="text",
        help="finding output format (default: text)",
    )
    ap.add_argument(
        "--baseline",
        default=baseline_mod.DEFAULT_BASELINE,
        help="ratchet baseline JSON (default: %(default)s)",
    )
    ap.add_argument(
        "--no-baseline",
        action="store_true",
        help="report every finding, grandfathered or not",
    )
    ap.add_argument(
        "--write-baseline",
        action="store_true",
        help="rewrite the baseline from current findings and exit 0 "
        "(the only sanctioned way to edit it)",
    )
    ap.add_argument(
        "--ratchet",
        metavar="REF",
        help="also fail if the committed baseline contains entries absent "
        "at git REF (the baseline may only shrink)",
    )
    ap.add_argument(
        "--list-rules", action="store_true", help="print the rule registry"
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        width = max(len(r.id) for r in RULES)
        for r in RULES:
            print(f"{r.id:<{width}}  {' '.join(r.description.split())}")
        print(
            f"{UNUSED_SUPPRESSION:<{width}}  An allow-comment that "
            "suppresses nothing is itself a finding."
        )
        return 0

    root = pathlib.Path.cwd()
    findings = lint_paths(args.paths, root=root)
    baseline_path = root / args.baseline

    if args.write_baseline:
        baseline_mod.dump(findings, baseline_path)
        print(f"wrote {len(findings)} finding(s) to {args.baseline}")
        return 0

    entries: List[dict] = []
    if not args.no_baseline and baseline_path.exists():
        entries = baseline_mod.load(baseline_path)
    new, grandfathered, stale = baseline_mod.split(findings, entries)

    report = REPORTERS[args.format](new)
    if report:
        print(report)
    errors = len(new)
    for rule, path, context in stale:
        errors += 1
        print(
            f"{args.baseline}: stale entry [{rule}] {path} ({context!r}) "
            "matches no current finding — shrink the baseline with "
            "--write-baseline",
            file=sys.stderr,
        )
    if args.ratchet:
        old = baseline_mod.at_git_ref(args.ratchet, root)
        if old is None:
            print(
                f"reprolint: no baseline at {args.ratchet} — ratchet "
                "skipped (first baseline commit)",
                file=sys.stderr,
            )
        else:
            for msg in baseline_mod.ratchet_errors(entries, old):
                errors += 1
                print(msg, file=sys.stderr)
    summary = (
        f"reprolint: {len(findings)} finding(s) "
        f"({len(new)} new, {len(grandfathered)} grandfathered, "
        f"{len(stale)} stale baseline entr{'y' if len(stale) == 1 else 'ies'})"
    )
    print(summary, file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
