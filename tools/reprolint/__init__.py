"""reprolint — the repo's AST-based invariant linter.

Turns the conventions documented in ``docs/ARCHITECTURE.md`` (layer
DAG, determinism discipline, spec contracts, oracle retention) into
machine-checked rules that fail CI *before* a bench gate ever runs.

Usage::

    python -m tools.reprolint src benchmarks tests examples
    python -m tools.reprolint --list-rules
    python -m tools.reprolint --format github          # CI annotations
    python -m tools.reprolint --write-baseline         # shrink the ratchet

Stdlib only.  See ``tools/reprolint/config.py`` for the declared layer
map / oracle map and ``README.md`` ("Static invariant lint") for the
suppression + ratchet workflow.
"""

from . import rules as _rules  # noqa: F401  (populates the registry)
from .core import (  # noqa: F401
    Finding,
    ModuleInfo,
    RULES,
    lint_module,
    lint_paths,
    lint_source,
    rule_ids,
)
