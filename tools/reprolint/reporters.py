"""Finding reporters: human text, machine JSON, GitHub annotations."""

from __future__ import annotations

import json
from typing import List, Sequence

from .core import Finding


def text(findings: Sequence[Finding]) -> str:
    return "\n".join(str(f) for f in findings)


def as_json(findings: Sequence[Finding]) -> str:
    return json.dumps(
        [
            {
                "rule": f.rule,
                "path": f.path,
                "line": f.line,
                "message": f.message,
                "context": f.context,
            }
            for f in findings
        ],
        indent=1,
    )


def github(findings: Sequence[Finding]) -> str:
    """``::error`` workflow commands — GitHub renders them as inline PR
    annotations.  Messages must be single-line; newlines are escaped per
    the workflow-command spec."""
    lines: List[str] = []
    for f in findings:
        msg = f.message.replace("%", "%25").replace("\n", "%0A")
        lines.append(
            f"::error file={f.path},line={f.line},"
            f"title=reprolint[{f.rule}]::{msg}"
        )
    return "\n".join(lines)


REPORTERS = {"text": text, "json": as_json, "github": github}
