"""Repo tooling: ``check_links.py`` (docs link check) and the
``tools.reprolint`` invariant linter (``python -m tools.reprolint``)."""
